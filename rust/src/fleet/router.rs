//! Request placement across engine shards.
//!
//! The router owns two things: the [`Placement`] discipline and the
//! per-shard [`ShardLoad`] counters it places against. Loads are shared
//! atomics maintained *cooperatively* by both sides of the fleet:
//!
//! * the router **reserves** a request's worst-case NFE cost on the chosen
//!   shard at placement time (`pending_*` — placed, not yet seen by the
//!   shard thread), so a burst of submissions spreads instead of piling
//!   onto whichever shard last published the lowest number;
//! * the shard thread **settles** the reservation when it picks the job
//!   up, and **publishes** its engine's live [`EngineLoad`]
//!   (`active`/`queued_nfes`) after every message and pump.
//!
//! A shard's load is the sum of both halves ([`ShardLoad::nfes`] /
//! [`ShardLoad::requests`]), which is exactly the quantity the engine's
//! own queued-NFE accounting converges to once the queue drains — the
//! same honest cost unit the admission budgets bound.
//!
//! Placement is deterministic: `least-loaded` breaks ties by lowest shard
//! index, `round-robin` cycles a counter over live shards, `client-hash`
//! is a stable FNV-1a over `client_id` (anonymous requests share the `""`
//! lane). Dead shards (backend construction failed, or a fatal pump
//! error) are skipped by every discipline.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

/// How the fleet router picks a shard for each request
/// (`agd serve --placement`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Lowest live queued-NFE snapshot (reservations included), ties by
    /// lowest shard index. The default.
    LeastLoaded,
    /// Cycle over live shards in index order.
    RoundRobin,
    /// Stable hash of `client_id` — keeps one client's requests on one
    /// shard (cache affinity; makes the per-client quota fleet-exact).
    ClientHash,
}

impl Placement {
    /// Every selectable placement, in display order.
    pub const ALL: [Placement; 3] = [
        Placement::LeastLoaded,
        Placement::RoundRobin,
        Placement::ClientHash,
    ];

    /// Wire name (matches [`Placement::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            Placement::LeastLoaded => "least-loaded",
            Placement::RoundRobin => "round-robin",
            Placement::ClientHash => "client-hash",
        }
    }

    /// Parse a `--placement` value.
    pub fn parse(s: &str) -> Result<Placement, String> {
        match s {
            "least-loaded" => Ok(Placement::LeastLoaded),
            "round-robin" => Ok(Placement::RoundRobin),
            "client-hash" => Ok(Placement::ClientHash),
            other => Err(format!(
                "unknown placement `{other}` (expected least-loaded|round-robin|client-hash)"
            )),
        }
    }
}

/// Shared per-shard load counters (see module docs). All reads are
/// advisory snapshots — exactness is not required for placement, only for
/// the *direction* of the signal, and every counter is eventually
/// consistent with the engine's own accounting.
#[derive(Debug, Default)]
pub struct ShardLoad {
    /// Requests placed by the router but not yet picked up by the shard.
    pending_jobs: AtomicUsize,
    /// Worst-case NFEs of those pending requests.
    pending_nfes: AtomicUsize,
    /// The shard engine's published `active` count.
    active: AtomicUsize,
    /// The shard engine's published `queued_nfes`.
    queued_nfes: AtomicUsize,
    /// Set when the shard thread died (failed construction or fatal pump
    /// error); placement skips dead shards.
    dead: AtomicBool,
    /// §Robustness: lifetime death count. `dead` is *state* (cleared by
    /// [`ShardLoad::revive`] when the supervisor respawns the shard);
    /// this is the monotonic *ledger* behind `shard_died_total`, so the
    /// history survives a respawn.
    died: AtomicU64,
}

impl ShardLoad {
    /// Router side: reserve a placed request's cost before sending it.
    pub fn reserve(&self, cost: usize) {
        self.pending_jobs.fetch_add(1, Ordering::Relaxed);
        self.pending_nfes.fetch_add(cost, Ordering::Relaxed);
    }

    /// Shard side (or router, on a failed send): the placed request has
    /// been picked up (admitted or refused) — the engine's published
    /// numbers now carry it, if it was admitted.
    pub fn settle(&self, cost: usize) {
        self.pending_jobs.fetch_sub(1, Ordering::Relaxed);
        self.pending_nfes.fetch_sub(cost, Ordering::Relaxed);
    }

    /// Shard side: publish the engine's live load snapshot.
    pub fn publish(&self, active: usize, queued_nfes: usize) {
        self.active.store(active, Ordering::Relaxed);
        self.queued_nfes.store(queued_nfes, Ordering::Relaxed);
    }

    /// Mark the shard dead (skipped by placement from now on) and zero its
    /// published load so fleet totals stop counting it. Counts one death
    /// per alive→dead transition, however many callers race to report it.
    pub fn mark_dead(&self) {
        if !self.dead.swap(true, Ordering::Relaxed) {
            self.died.fetch_add(1, Ordering::Relaxed);
        }
        self.publish(0, 0);
    }

    /// §Robustness: the supervisor respawned this shard — make it
    /// placeable again. The death ledger ([`ShardLoad::died`]) is kept.
    pub fn revive(&self) {
        self.dead.store(false, Ordering::Relaxed);
    }

    pub fn is_dead(&self) -> bool {
        self.dead.load(Ordering::Relaxed)
    }

    /// Lifetime alive→dead transitions (survives [`ShardLoad::revive`]).
    pub fn died(&self) -> u64 {
        self.died.load(Ordering::Relaxed)
    }

    /// Live queued-NFE estimate: engine-published + router reservations.
    pub fn nfes(&self) -> usize {
        self.queued_nfes.load(Ordering::Relaxed) + self.pending_nfes.load(Ordering::Relaxed)
    }

    /// Live request estimate: engine-published + router reservations.
    pub fn requests(&self) -> usize {
        self.active.load(Ordering::Relaxed) + self.pending_jobs.load(Ordering::Relaxed)
    }
}

/// Stable FNV-1a 64 over the client id — placement must not drift across
/// runs or platforms, so no `DefaultHasher`.
fn client_hash(client: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in client.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Placement state — one per fleet, behind the fleet's router lock.
#[derive(Debug)]
pub struct Router {
    placement: Placement,
    rr_next: usize,
}

impl Router {
    pub fn new(placement: Placement) -> Router {
        Router {
            placement,
            rr_next: 0,
        }
    }

    pub fn placement(&self) -> Placement {
        self.placement
    }

    /// Pick a shard for one request; `None` when every shard is dead.
    /// Deterministic given the same load snapshots and call sequence.
    pub fn place<L: AsRef<ShardLoad>>(&mut self, loads: &[L], client: Option<&str>) -> Option<usize> {
        let n = loads.len();
        let alive = |i: usize| !loads[i].as_ref().is_dead();
        if !(0..n).any(alive) {
            return None;
        }
        match self.placement {
            Placement::LeastLoaded => (0..n)
                .filter(|&i| alive(i))
                .min_by_key(|&i| (loads[i].as_ref().nfes(), i)),
            Placement::RoundRobin => {
                // cycle the counter but never hand out a dead shard
                for _ in 0..n {
                    let i = self.rr_next % n;
                    self.rr_next = self.rr_next.wrapping_add(1);
                    if alive(i) {
                        return Some(i);
                    }
                }
                None
            }
            Placement::ClientHash => {
                let h = client_hash(client.unwrap_or(""));
                let start = (h % n as u64) as usize;
                // a dead home shard falls through to the next live one, so
                // affinity degrades gracefully instead of erroring
                (0..n).map(|k| (start + k) % n).find(|&i| alive(i))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn loads(n: usize) -> Vec<Arc<ShardLoad>> {
        (0..n).map(|_| Arc::new(ShardLoad::default())).collect()
    }

    #[test]
    fn placement_names_round_trip() {
        for p in Placement::ALL {
            assert_eq!(Placement::parse(p.name()), Ok(p));
        }
        let err = Placement::parse("warp").unwrap_err();
        assert!(err.contains("least-loaded"), "{err}");
    }

    /// The satellite pin: least-loaded follows the queued-NFE snapshots —
    /// both the engine-published half and the router's own reservations.
    #[test]
    fn least_loaded_tracks_queued_nfe_snapshots() {
        let ls = loads(3);
        let mut r = Router::new(Placement::LeastLoaded);
        // all empty → lowest index wins
        assert_eq!(r.place(&ls, None), Some(0));
        // a reservation on 0 moves placement to 1, and so on
        ls[0].reserve(40);
        assert_eq!(r.place(&ls, None), Some(1));
        ls[1].reserve(40);
        assert_eq!(r.place(&ls, None), Some(2));
        ls[2].reserve(60);
        // 0 and 1 tie at 40 → lowest index
        assert_eq!(r.place(&ls, None), Some(0));
        // the shard settling its reservation hands the load to the
        // engine-published half; the router keeps seeing the same total
        ls[0].settle(40);
        ls[0].publish(1, 40);
        assert_eq!(ls[0].nfes(), 40);
        assert_eq!(r.place(&ls, None), Some(0));
        // engine progress (published queued shrinking) re-attracts work
        ls[2].settle(60);
        ls[2].publish(1, 4);
        assert_eq!(r.place(&ls, None), Some(2));
        // dead shards are skipped even when least loaded
        ls[2].mark_dead();
        assert_eq!(ls[2].nfes(), 0);
        assert_eq!(r.place(&ls, None), Some(0));
    }

    #[test]
    fn round_robin_cycles_live_shards() {
        let ls = loads(3);
        let mut r = Router::new(Placement::RoundRobin);
        let seq: Vec<_> = (0..6).map(|_| r.place(&ls, None).unwrap()).collect();
        assert_eq!(seq, vec![0, 1, 2, 0, 1, 2]);
        ls[1].mark_dead();
        let seq: Vec<_> = (0..4).map(|_| r.place(&ls, None).unwrap()).collect();
        assert_eq!(seq, vec![0, 2, 0, 2]);
    }

    #[test]
    fn client_hash_is_sticky_and_survives_dead_shards() {
        let ls = loads(4);
        let mut r = Router::new(Placement::ClientHash);
        let home = r.place(&ls, Some("web-7")).unwrap();
        for _ in 0..5 {
            assert_eq!(r.place(&ls, Some("web-7")), Some(home));
        }
        // anonymous requests share one lane
        let anon = r.place(&ls, None).unwrap();
        assert_eq!(r.place(&ls, Some("")), Some(anon));
        // a dead home shard falls through deterministically
        ls[home].mark_dead();
        let fallback = r.place(&ls, Some("web-7")).unwrap();
        assert_ne!(fallback, home);
        assert_eq!(r.place(&ls, Some("web-7")), Some(fallback));
    }

    #[test]
    fn revive_restores_placement_but_keeps_the_death_ledger() {
        let ls = loads(2);
        let mut r = Router::new(Placement::LeastLoaded);
        ls[0].mark_dead();
        ls[0].mark_dead(); // double-report: still one recorded death
        assert_eq!(ls[0].died(), 1);
        assert_eq!(r.place(&ls, None), Some(1));
        ls[0].revive();
        assert!(!ls[0].is_dead());
        assert_eq!(ls[0].died(), 1, "revive must not erase the ledger");
        assert_eq!(r.place(&ls, None), Some(0));
        // a second crash counts again
        ls[0].mark_dead();
        assert_eq!(ls[0].died(), 2);
    }

    #[test]
    fn all_dead_yields_none() {
        let ls = loads(2);
        ls[0].mark_dead();
        ls[1].mark_dead();
        for p in Placement::ALL {
            assert_eq!(Router::new(p).place(&ls, Some("x")), None, "{}", p.name());
        }
    }
}
