//! §Scale: the engine fleet — N engine replicas behind a load-aware router.
//!
//! One engine is one thread is (in production) one device: the PJRT client
//! is thread-affine, so scaling the serving stack out means *replicating*
//! the whole engine — backend instance, scheduler, worker pool, buffer
//! pool — once per shard and routing requests between the replicas. This
//! module owns that topology:
//!
//! ```text
//!   connections ──► Fleet::submit ──► router (placement + global budget)
//!                                       │ per-shard mpsc
//!                      ┌────────────────┼────────────────┐
//!                  shard 0          shard 1     …     shard N-1
//!               (engine thread)  (engine thread)   (engine thread)
//!                  [`replica`]      backend/scheduler/pools per shard
//! ```
//!
//! * **Placement** ([`router`]): `least-loaded` (default; lowest live
//!   queued-NFE snapshot), `round-robin`, or `client-hash` (cache
//!   affinity — one client always lands on one shard). Snapshots combine
//!   the engine-published load with the router's own in-flight
//!   reservations, so bursts spread correctly.
//! * **Two-level admission**: the router checks a fleet-global
//!   [`Admission`] budget against the summed shard loads before placing;
//!   each shard engine then enforces its own per-shard budget (and the
//!   per-client quota). Shed lines carry `"scope": "global"|"shard"`
//!   ([`ScopedShed`]).
//! * **Telemetry aggregation**: `{"cmd": "stats"}` / `{"cmd": "metrics"}`
//!   merge every shard's registry ([`Telemetry::absorb`]) — each series
//!   appears under its `shard=` label and summed into a fleet total.
//! * **Drain/shutdown**: [`Fleet::drain`] stops admissions (new requests
//!   get a `draining` error) and blocks until every shard is idle —
//!   in-flight work always completes; [`Fleet::shutdown`] drains and then
//!   joins every engine thread.
//!
//! The load-bearing invariant: **placement never changes results**. A
//! request's output depends only on its own seed and policy — batching
//! packs rows, it never mixes math across them — so completions are
//! byte-identical for every `--shards` count and every placement
//! (pinned by `rust/tests/fleet_integration.rs` against the golden
//! unfused sampler).
//!
//! The invariant holds under *failure* too, and is exercised on purpose:
//! [`Fleet::kill_shard`] injects a crash into a live shard (the chaos
//! harness's hook — [`crate::chaos`]), which runs the same fatal path as
//! a real pump failure: in-flight jobs on the victim are refused with
//! `"code": "shard_failed"` ([`ShardFailed`]), the shard is marked dead
//! (visible as `shard_died_total{shard=}` and a dropped
//! `fleet_shards_alive`), and the survivors keep serving byte-identical
//! completions (`rust/tests/chaos_integration.rs`).

pub mod replica;
pub mod router;

use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::backend::Backend;
use crate::coordinator::engine::{Engine, MAX_STEPS};
use crate::coordinator::request::Request;
use crate::sched::{Admission, AdmitError, SchedulerKind, Telemetry};
use crate::util::json::{self, Value};

pub use replica::{Job, JobReply, ShardStats};
pub use router::{Placement, Router, ShardLoad};

use replica::ShardMsg;

/// An admission shed tagged with the level that made it: `"global"` (the
/// router's fleet-wide budget) or `"shard"` (one engine's own budget).
/// The server surfaces the scope as a `"scope"` field on the shed line.
#[derive(Debug, Clone)]
pub struct ScopedShed {
    pub scope: &'static str,
    pub inner: AdmitError,
}

impl fmt::Display for ScopedShed {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)
    }
}

impl std::error::Error for ScopedShed {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        Some(&self.inner)
    }
}

/// A shard's engine died with work in flight — the jobs it was holding
/// are refused with this error (`"code": "shard_failed"` on the wire)
/// rather than silently dropped. Raised by a fatal pump error or an
/// injected [`Fleet::kill_shard`] crash; the rest of the fleet keeps
/// serving.
#[derive(Debug, Clone)]
pub struct ShardFailed {
    pub shard: usize,
    pub reason: String,
}

impl fmt::Display for ShardFailed {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "shard {} failed: {}", self.shard, self.reason)
    }
}

impl std::error::Error for ShardFailed {}

/// Routing-level refusals that are not admission sheds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteError {
    /// `{"cmd": "drain"}` has run (or is running): no new admissions.
    Draining,
    /// Every shard is gone (all dead, or the fleet was shut down).
    Closed,
}

impl fmt::Display for RouteError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RouteError::Draining => {
                write!(f, "server is draining: not admitting new requests")
            }
            RouteError::Closed => write!(f, "engine fleet is shut down"),
        }
    }
}

impl std::error::Error for RouteError {}

/// Fleet topology + budgets (`agd serve --shards/--placement/...`).
#[derive(Debug, Clone, Copy)]
pub struct FleetConfig {
    /// Engine replicas (`--shards`; min 1).
    pub shards: usize,
    /// Request placement discipline (`--placement`).
    pub placement: Placement,
    /// Scheduling discipline inside every shard (`--scheduler`).
    pub scheduler: SchedulerKind,
    /// Fleet-global budgets, checked at the router (`--max-in-flight`,
    /// `--max-queued-nfes`). Its `max_in_flight_per_client` member is
    /// ignored here — the per-client quota is shard-side.
    pub global_admission: Admission,
    /// Per-shard engine budgets (`--shard-max-in-flight`,
    /// `--shard-max-queued-nfes`), plus the per-client quota.
    pub shard_admission: Admission,
    /// Worker lanes per shard (`--workers`); 0 = available parallelism
    /// divided by the shard count (each shard owns its own pool).
    pub workers: usize,
    /// Shed deadline-infeasible requests at shard admission
    /// (`--shed-infeasible`).
    pub shed_infeasible: bool,
}

impl Default for FleetConfig {
    fn default() -> FleetConfig {
        FleetConfig {
            shards: 1,
            placement: Placement::LeastLoaded,
            scheduler: SchedulerKind::Fifo,
            global_admission: Admission::unlimited(),
            shard_admission: Admission::unlimited(),
            workers: 1,
            shed_infeasible: false,
        }
    }
}

/// The mutable router half: placement state + the shard channels.
/// One mutex guards both — placement, reservation and send happen as one
/// atomic step, which is what makes least-loaded deterministic under
/// concurrent submitters (and keeps `Fleet: Sync` on toolchains where
/// `mpsc::Sender` is not).
struct RouterInner {
    router: Router,
    txs: Vec<std::sync::mpsc::Sender<ShardMsg>>,
}

/// The engine fleet (see module docs). Shared across connection-handler
/// threads behind an `Arc`; every public method takes `&self`.
pub struct Fleet {
    loads: Vec<Arc<ShardLoad>>,
    joins: Mutex<Vec<JoinHandle<()>>>,
    router: Mutex<RouterInner>,
    global: Admission,
    placement: Placement,
    scheduler: SchedulerKind,
    draining: AtomicBool,
    next_id: AtomicU64,
    /// Launch instant — `uptime_s` in `{"cmd": "stats"}`.
    started: Instant,
    /// Fleet-level counters that belong to no shard engine: connection
    /// hygiene (`conn_*`, incremented by the server's handlers) and
    /// chaos injections (`chaos_*`). Merged into `{"cmd": "stats"}` /
    /// `{"cmd": "metrics"}` alongside the shard registries.
    telemetry: Mutex<Telemetry>,
}

impl Fleet {
    /// Spawn `cfg.shards` engine threads, each constructing its own
    /// backend via `factory(shard_index)` *inside* the thread (the PJRT
    /// client must be born where it runs; the index is the hook for
    /// one-device-per-shard deployments). A shard whose construction
    /// fails is marked dead and skipped by placement — the fleet serves
    /// on the survivors, and [`Fleet::submit`] errors only when every
    /// shard is dead.
    pub fn launch<B, F>(factory: F, cfg: FleetConfig) -> Fleet
    where
        B: Backend + 'static,
        F: Fn(usize) -> Result<B> + Send + Sync + 'static,
    {
        let n = cfg.shards.max(1);
        let workers = if cfg.workers == 0 {
            (crate::exec::default_workers() / n).max(1)
        } else {
            cfg.workers
        };
        let factory = Arc::new(factory);
        let mut txs = Vec::with_capacity(n);
        let mut loads = Vec::with_capacity(n);
        let mut joins = Vec::with_capacity(n);
        for i in 0..n {
            let (tx, rx) = channel::<ShardMsg>();
            let load = Arc::new(ShardLoad::default());
            let (f, l) = (factory.clone(), load.clone());
            let (kind, adm, shed) = (cfg.scheduler, cfg.shard_admission, cfg.shed_infeasible);
            let join = std::thread::Builder::new()
                .name(format!("agd-shard-{i}"))
                .spawn(move || {
                    let engine =
                        f(i).and_then(|be| Engine::with_scheduler(be, kind.build(), adm));
                    match engine {
                        Ok(mut engine) => {
                            engine.set_workers(workers);
                            replica::run_replica(i, engine, rx, l, shed);
                        }
                        Err(e) => {
                            log::error!("shard {i}: backend construction failed: {e:#}");
                            l.mark_dead();
                        }
                    }
                })
                .expect("spawn shard thread");
            txs.push(tx);
            loads.push(load);
            joins.push(join);
        }
        Fleet {
            loads,
            joins: Mutex::new(joins),
            router: Mutex::new(RouterInner {
                router: Router::new(cfg.placement),
                txs,
            }),
            global: cfg.global_admission,
            placement: cfg.placement,
            scheduler: cfg.scheduler,
            draining: AtomicBool::new(false),
            next_id: AtomicU64::new(0),
            started: Instant::now(),
            telemetry: Mutex::new(Telemetry::new()),
        }
    }

    /// Bump a fleet-level counter (connection hygiene, chaos injections).
    /// Fleet-level because dead shards are skipped by stats collection —
    /// a counter living in a dying engine's registry would never be
    /// scraped again.
    pub fn count(&self, name: &str, labels: &[(&str, &str)]) {
        self.telemetry
            .lock()
            .expect("fleet telemetry lock")
            .inc(name, labels, 1);
    }

    /// Inject a crash into a live shard — the chaos harness's fault hook
    /// ([`crate::chaos::Director`]'s `kill-shard` op). The shard runs its
    /// real fatal path between batch steps: in-flight jobs are refused
    /// with `"code": "shard_failed"` and the shard is marked dead, while
    /// the rest of the fleet keeps serving. Returns `false` when the
    /// index is out of range or the shard is already dead. Jobs placed
    /// before this call are guaranteed to reach the shard first (one
    /// FIFO channel per shard), so a mid-flight kill always exercises the
    /// refusal path, never a silent drop.
    pub fn kill_shard(&self, shard: usize) -> bool {
        {
            let guard = self.router.lock().expect("router lock");
            if shard >= self.loads.len() || self.loads[shard].is_dead() {
                return false;
            }
            if guard.txs[shard].send(ShardMsg::Crash).is_err() {
                // channel gone without a death mark (shutdown race)
                self.loads[shard].mark_dead();
                return false;
            }
        }
        let label = shard.to_string();
        self.count("chaos_kill_shard_total", &[("shard", &label)]);
        true
    }

    pub fn shards(&self) -> usize {
        self.loads.len()
    }

    pub fn placement(&self) -> Placement {
        self.placement
    }

    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// Fleet-wide request count (live shards only; reservations included).
    fn total_requests(&self) -> usize {
        self.loads
            .iter()
            .filter(|l| !l.is_dead())
            .map(|l| l.requests())
            .sum()
    }

    /// Fleet-wide queued-NFE estimate (live shards only).
    fn total_nfes(&self) -> usize {
        self.loads
            .iter()
            .filter(|l| !l.is_dead())
            .map(|l| l.nfes())
            .sum()
    }

    /// Route one request: global admission → placement → reservation →
    /// shard channel. Returns the reply channel the shard will answer on
    /// ([`JobReply::Done`] with the bit-exact [`Completion`], or
    /// [`JobReply::Error`] with the protocol line). Errors here are
    /// router-level: [`RouteError::Draining`]/[`RouteError::Closed`] or a
    /// global-scope [`ScopedShed`].
    pub fn submit(&self, mut req: Request) -> Result<Receiver<JobReply>> {
        // §Observability: the admission and placement stage durations are
        // stamped onto traced requests; the shard engine reconstructs
        // start times from them (the queue stage is stamped shard-side)
        let t_admit = Instant::now();
        // worst-case cost, for the global budget and the reservation; a
        // step count the engine would refuse anyway reserves nothing (and
        // skips the O(steps) plan walk on the router thread)
        let cost = if req.steps >= 1 && req.steps <= MAX_STEPS {
            req.policy.max_nfes(req.steps)
        } else {
            0
        };
        let mut guard = self.router.lock().expect("router lock");
        if self.is_draining() {
            return Err(anyhow::Error::new(RouteError::Draining));
        }
        if let Err(inner) = self
            .global
            .check(self.total_requests(), self.total_nfes(), cost)
        {
            return Err(anyhow::Error::new(ScopedShed {
                scope: "global",
                inner,
            }));
        }
        let t_place = Instant::now();
        let Some(idx) = guard.router.place(&self.loads, req.client_id.as_deref()) else {
            return Err(anyhow::Error::new(RouteError::Closed));
        };
        if req.trace {
            req.span_admission_us =
                t_place.saturating_duration_since(t_admit).as_micros() as u64;
            req.span_placement_us = t_place.elapsed().as_micros() as u64;
        }
        req.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let load = &self.loads[idx];
        load.reserve(cost);
        let (rtx, rrx) = channel();
        let job = Job {
            req,
            cost,
            started: Instant::now(),
            reply: rtx,
        };
        if guard.txs[idx].send(ShardMsg::Job(job)).is_err() {
            load.settle(cost);
            load.mark_dead();
            return Err(anyhow::Error::new(RouteError::Closed));
        }
        Ok(rrx)
    }

    /// Clone the shard channels out of the router lock, so slow follow-up
    /// work (waiting on stats/drain acks) never blocks placement.
    fn channels(&self) -> Vec<std::sync::mpsc::Sender<ShardMsg>> {
        self.router.lock().expect("router lock").txs.clone()
    }

    /// Collect every live shard's stats snapshot.
    fn collect(&self) -> Result<Vec<ShardStats>> {
        let mut rxs = Vec::new();
        for (tx, load) in self.channels().iter().zip(&self.loads) {
            if load.is_dead() {
                continue;
            }
            let (rtx, rx) = channel();
            if tx.send(ShardMsg::Stats(rtx)).is_ok() {
                rxs.push(rx);
            }
        }
        let stats: Vec<ShardStats> = rxs.into_iter().filter_map(|rx| rx.recv().ok()).collect();
        anyhow::ensure!(!stats.is_empty(), "engine fleet is shut down");
        Ok(stats)
    }

    /// §Observability: drain every live shard's span ring
    /// (`{"cmd": "spans"}`). Each batch arrives stamped with its shard id;
    /// serialize with [`crate::trace::batches_to_json`].
    pub fn drain_spans(&self) -> Result<Vec<crate::trace::SpanBatch>> {
        let mut rxs = Vec::new();
        for (tx, load) in self.channels().iter().zip(&self.loads) {
            if load.is_dead() {
                continue;
            }
            let (rtx, rx) = channel();
            if tx.send(ShardMsg::Spans(rtx)).is_ok() {
                rxs.push(rx);
            }
        }
        let batches: Vec<crate::trace::SpanBatch> =
            rxs.into_iter().filter_map(|rx| rx.recv().ok()).collect();
        anyhow::ensure!(!batches.is_empty(), "engine fleet is shut down");
        Ok(batches)
    }

    /// Merge shard registries: fleet totals (unlabelled) + per-shard
    /// series under `shard=` labels, plus the fleet topology gauges.
    /// Gauges exist only under their `shard=` label (intensive gauges
    /// have no meaningful sum — see [`Telemetry::absorb`]); the extensive
    /// fleet totals are published here from the scalar snapshots.
    fn merged_telemetry(&self, stats: &[ShardStats]) -> Telemetry {
        let mut merged = Telemetry::new();
        for st in stats {
            merged.absorb(&st.telemetry, None);
        }
        for st in stats {
            let shard = st.shard.to_string();
            merged.absorb(&st.telemetry, Some(("shard", &shard)));
        }
        // fleet-level counters (conn_*, chaos_*) ride along unlabelled
        {
            let own = self.telemetry.lock().expect("fleet telemetry lock");
            merged.absorb(&own, None);
        }
        // dead shards answer no Stats message, so their death is derived
        // here from the load flag instead of counted in a registry nobody
        // can scrape: one series per dead shard, pinned at 1
        for (i, load) in self.loads.iter().enumerate() {
            if load.is_dead() {
                let shard = i.to_string();
                merged.inc("shard_died_total", &[("shard", &shard)], 1);
            }
        }
        let sum = |f: &dyn Fn(&ShardStats) -> usize| stats.iter().map(f).sum::<usize>() as f64;
        merged.set_gauge("active_requests", &[], sum(&|t| t.active));
        merged.set_gauge("queue_depth", &[], sum(&|t| t.queue_depth));
        merged.set_gauge("queued_nfes", &[], sum(&|t| t.queued_nfes));
        merged.set_gauge("fleet_shards", &[], self.loads.len() as f64);
        merged.set_gauge(
            "fleet_shards_alive",
            &[],
            self.loads.iter().filter(|l| !l.is_dead()).count() as f64,
        );
        merged
    }

    /// `{"cmd": "stats"}`: fleet totals, per-shard breakdown, and the
    /// merged telemetry registry.
    pub fn stats_json(&self) -> Result<Value> {
        use crate::util::json::{arr, num, obj, s};
        let stats = self.collect()?;
        let sum = |f: &dyn Fn(&ShardStats) -> usize| stats.iter().map(f).sum::<usize>();
        let (batches, items) = (sum(&|t| t.batches), sum(&|t| t.items));
        let spans_dropped: u64 = stats.iter().map(|t| t.spans_dropped).sum();
        let per_shard: Vec<Value> = stats
            .iter()
            .map(|t| {
                obj(vec![
                    ("shard", num(t.shard as f64)),
                    ("active", num(t.active as f64)),
                    ("queue_depth", num(t.queue_depth as f64)),
                    ("queued_nfes", num(t.queued_nfes as f64)),
                    ("batches", num(t.batches as f64)),
                    ("items", num(t.items as f64)),
                    ("mean_occupancy", num(t.mean_occupancy)),
                    ("spans_dropped_total", num(t.spans_dropped as f64)),
                ])
            })
            .collect();
        let telemetry = self.merged_telemetry(&stats);
        Ok(obj(vec![
            ("scheduler", s(self.scheduler.name())),
            ("version", s(env!("CARGO_PKG_VERSION"))),
            ("uptime_s", num(self.started.elapsed().as_secs_f64())),
            ("shards", num(self.loads.len() as f64)),
            ("placement", s(self.placement().name())),
            ("draining", json::Value::Bool(self.is_draining())),
            ("active", num(sum(&|t| t.active) as f64)),
            ("queue_depth", num(sum(&|t| t.queue_depth) as f64)),
            ("queued_nfes", num(sum(&|t| t.queued_nfes) as f64)),
            ("batches", num(batches as f64)),
            ("items", num(items as f64)),
            (
                "mean_occupancy",
                num(if batches == 0 {
                    0.0
                } else {
                    items as f64 / batches as f64
                }),
            ),
            ("spans_dropped_total", num(spans_dropped as f64)),
            ("per_shard", arr(per_shard)),
            ("telemetry", telemetry.to_json()),
        ]))
    }

    /// `{"cmd": "metrics"}`: Prometheus exposition of the merged registry
    /// (fleet totals + `shard=`-labelled per-shard series).
    pub fn metrics_prometheus(&self) -> Result<String> {
        let stats = self.collect()?;
        Ok(self.merged_telemetry(&stats).to_prometheus())
    }

    /// Stop admitting (subsequent submits get a `draining` error) and
    /// block until every shard is idle. In-flight work always completes —
    /// each shard acknowledges only once its engine has nothing queued or
    /// executing. Idempotent; returns the shard count.
    pub fn drain(&self) -> usize {
        {
            // serialize with in-progress submits: a request that won the
            // router lock before us reaches its shard's channel ahead of
            // the Drain message and is therefore waited for
            let _guard = self.router.lock().expect("router lock");
            self.draining.store(true, Ordering::SeqCst);
        }
        let mut acks = Vec::new();
        for tx in self.channels() {
            let (rtx, rx) = channel();
            if tx.send(ShardMsg::Drain(rtx)).is_ok() {
                acks.push(rx);
            }
        }
        for rx in acks {
            let _ = rx.recv();
        }
        self.loads.len()
    }

    /// Drain, then join every engine thread. The graceful teardown path —
    /// wired into `{"cmd": "drain"}`-driven shutdown and used by tests to
    /// close a fleet without leaking threads. Idempotent.
    pub fn shutdown(&self) -> usize {
        let n = self.drain();
        for tx in self.channels() {
            let _ = tx.send(ShardMsg::Shutdown);
        }
        let mut joins = self.joins.lock().expect("joins lock");
        for j in joins.drain(..) {
            let _ = j.join();
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::GmmBackend;
    use crate::coordinator::policy::cfg;
    use crate::sim::gmm::Gmm;

    fn fleet(n: usize, placement: Placement) -> Fleet {
        Fleet::launch(
            |_shard| Ok(GmmBackend::new(Gmm::axes(8, 4, 3.0, 0.05))),
            FleetConfig {
                shards: n,
                placement,
                ..FleetConfig::default()
            },
        )
    }

    fn req(comp: i32, steps: usize) -> Request {
        // ids are fleet-assigned; the 0 here is overwritten at submit
        Request::new(0, "gmm", vec![comp, 0, 0, 0], 100 + comp as u64, steps, cfg(2.0))
    }

    #[test]
    fn fleet_serves_and_shuts_down() {
        let fleet = fleet(2, Placement::RoundRobin);
        let rxs: Vec<_> = (0..4).map(|i| fleet.submit(req(1 + i % 4, 6)).unwrap()).collect();
        for rx in rxs {
            match rx.recv().unwrap() {
                JobReply::Done(c, ms) => {
                    assert_eq!(c.nfes, 12);
                    assert!(ms >= 0.0);
                }
                JobReply::Error(line) => panic!("unexpected error: {line}"),
            }
        }
        let stats = fleet.stats_json().unwrap();
        assert_eq!(stats.req("shards").as_f64(), Some(2.0));
        assert_eq!(stats.req("active").as_f64(), Some(0.0));
        assert_eq!(stats.req("placement").as_str(), Some("round-robin"));
        // both shards saw work under round-robin
        let per = stats.req("per_shard").as_arr().unwrap();
        assert_eq!(per.len(), 2);
        assert!(per.iter().all(|s| s.req("items").as_f64().unwrap() > 0.0));
        // prometheus carries fleet totals and shard-labelled series
        let prom = fleet.metrics_prometheus().unwrap();
        assert!(prom.contains("fleet_shards 2"), "{prom}");
        assert!(prom.contains("shard=\"0\""), "{prom}");
        assert!(prom.contains("shard=\"1\""), "{prom}");

        assert_eq!(fleet.shutdown(), 2);
        // post-shutdown: draining error, stats unavailable
        let err = fleet.submit(req(1, 4)).unwrap_err();
        assert!(err.downcast_ref::<RouteError>() == Some(&RouteError::Draining), "{err}");
        assert!(fleet.stats_json().is_err());
        // idempotent
        assert_eq!(fleet.shutdown(), 2);
    }

    #[test]
    fn traced_requests_span_the_fleet_and_stats_carry_uptime() {
        let fleet = fleet(2, Placement::RoundRobin);
        let rxs: Vec<_> = (0..4)
            .map(|i| {
                let mut r = req(1 + i % 4, 6);
                r.trace = true;
                fleet.submit(r).unwrap()
            })
            .collect();
        for rx in rxs {
            match rx.recv().unwrap() {
                JobReply::Done(c, _) => {
                    let tl = c.timeline.as_ref().expect("traced timeline");
                    let rows = tl.as_arr().unwrap();
                    // every lifecycle stage appears, including the three
                    // front-end stages the fleet stamped
                    for stage in crate::trace::Stage::ALL {
                        assert!(
                            rows.iter().any(|v| v.req("type").as_str() == Some("span")
                                && v.req("stage").as_str() == Some(stage.name())),
                            "missing {} in {tl:?}",
                            stage.name()
                        );
                    }
                }
                JobReply::Error(line) => panic!("{line}"),
            }
        }
        // spans drained per shard, stamped with their shard ids
        let batches = fleet.drain_spans().unwrap();
        assert_eq!(batches.len(), 2);
        let shards: Vec<usize> = batches.iter().map(|b| b.shard).collect();
        assert!(shards.contains(&0) && shards.contains(&1), "{shards:?}");
        assert!(
            batches.iter().all(|b| !b.events.is_empty()),
            "round-robin put traced work on both shards"
        );
        // a second drain is empty (the rings cleared), drops still zero
        let again = fleet.drain_spans().unwrap();
        assert!(again.iter().all(|b| b.events.is_empty()));
        // the stats satellite: uptime, crate version, per-shard drops
        let stats = fleet.stats_json().unwrap();
        assert!(stats.req("uptime_s").as_f64().unwrap() >= 0.0);
        assert_eq!(
            stats.req("version").as_str(),
            Some(env!("CARGO_PKG_VERSION"))
        );
        assert_eq!(stats.req("spans_dropped_total").as_f64(), Some(0.0));
        for sh in stats.req("per_shard").as_arr().unwrap() {
            assert_eq!(sh.req("spans_dropped_total").as_f64(), Some(0.0));
        }
        fleet.shutdown();
        assert!(fleet.drain_spans().is_err(), "shut-down fleet has no rings");
    }

    #[test]
    fn drain_blocks_new_work_but_finishes_old() {
        let fleet = fleet(2, Placement::LeastLoaded);
        let rx = fleet.submit(req(2, 12)).unwrap();
        assert_eq!(fleet.drain(), 2);
        // the in-flight request completed rather than being dropped
        match rx.recv().unwrap() {
            JobReply::Done(c, _) => assert_eq!(c.nfes, 24),
            JobReply::Error(line) => panic!("{line}"),
        }
        let err = fleet.submit(req(1, 4)).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<RouteError>(),
            Some(RouteError::Draining)
        ));
        // stats still answer while drained-but-not-joined
        assert!(fleet.stats_json().unwrap().req("draining").as_bool() == Some(true));
        fleet.shutdown();
    }
}
