//! §Scale: the engine fleet — N engine replicas behind a load-aware router.
//!
//! One engine is one thread is (in production) one device: the PJRT client
//! is thread-affine, so scaling the serving stack out means *replicating*
//! the whole engine — backend instance, scheduler, worker pool, buffer
//! pool — once per shard and routing requests between the replicas. This
//! module owns that topology:
//!
//! ```text
//!   connections ──► Fleet::submit ──► router (placement + global budget)
//!                                       │ per-shard mpsc
//!                      ┌────────────────┼────────────────┐
//!                  shard 0          shard 1     …     shard N-1
//!               (engine thread)  (engine thread)   (engine thread)
//!                  [`replica`]      backend/scheduler/pools per shard
//! ```
//!
//! * **Placement** ([`router`]): `least-loaded` (default; lowest live
//!   queued-NFE snapshot), `round-robin`, or `client-hash` (cache
//!   affinity — one client always lands on one shard). Snapshots combine
//!   the engine-published load with the router's own in-flight
//!   reservations, so bursts spread correctly.
//! * **Two-level admission**: the router checks a fleet-global
//!   [`Admission`] budget against the summed shard loads before placing;
//!   each shard engine then enforces its own per-shard budget (and the
//!   per-client quota). Shed lines carry `"scope": "global"|"shard"`
//!   ([`ScopedShed`]).
//! * **Telemetry aggregation**: `{"cmd": "stats"}` / `{"cmd": "metrics"}`
//!   merge every shard's registry ([`Telemetry::absorb`]) — each series
//!   appears under its `shard=` label and summed into a fleet total.
//! * **Drain/shutdown**: [`Fleet::drain`] stops admissions (new requests
//!   get a `draining` error) and blocks until every shard is idle —
//!   in-flight work always completes; [`Fleet::shutdown`] drains and then
//!   joins every engine thread.
//!
//! The load-bearing invariant: **placement never changes results**. A
//! request's output depends only on its own seed and policy — batching
//! packs rows, it never mixes math across them — so completions are
//! byte-identical for every `--shards` count and every placement
//! (pinned by `rust/tests/fleet_integration.rs` against the golden
//! unfused sampler).
//!
//! The invariant holds under *failure* too, and is exercised on purpose:
//! [`Fleet::kill_shard`] injects a crash into a live shard (the chaos
//! harness's hook — [`crate::chaos`]), which runs the same fatal path as
//! a real pump failure: the shard is marked dead (visible as
//! `shard_died_total{shard=}` and a dropped `fleet_shards_alive`) and
//! the survivors keep serving byte-identical completions
//! (`rust/tests/chaos_integration.rs`).
//!
//! §Robustness (`docs/ROBUSTNESS.md`): a death sheds as little as it
//! can. The dying shard salvages every admitted job that never started
//! executing and hands it to the fleet **supervisor** thread, which
//! re-places the jobs onto survivors — restarted from step 0 with the
//! same init noise, their completions stay byte-identical
//! (`jobs_salvaged_total{shard=}`). With `--checkpoint-steps N` the
//! engine also snapshots every started request's solver cursor each N
//! completed steps ([`crate::coordinator::checkpoint`]), so mid-flight
//! work is salvaged too: re-placed with its checkpoint, a survivor
//! resumes the trajectory at the recorded step and still completes
//! byte-identically (`jobs_resumed_total{shard=}`, `resume_step`
//! histogram). Only started work without a usable checkpoint is refused
//! with `"code": "shard_failed"` ([`ShardFailed`]). With
//! `--shard-respawn` the supervisor then rebuilds the dead shard from
//! the retained backend factory under capped exponential backoff, runs
//! one synthetic warm-up eval (`shard_warmup_ms`), and revives it for
//! placement (`shard_respawned_total{shard=}`).

pub mod replica;
pub mod router;

use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::backend::{Backend, BatchBuf, BatchOut};
use crate::chaos::fault::FaultPlan;
use crate::coordinator::engine::{
    Engine, DEFAULT_RETRY_BASE_MS, DEFAULT_RETRY_CAP_MS, MAX_STEPS,
};
use crate::coordinator::request::Request;
use crate::sched::{Admission, AdmitError, SchedulerKind, Telemetry};
use crate::util::json::{self, Value};
use crate::util::logev::log_event;

pub use replica::{Job, JobReply, ReplyTarget, ReplyTo, ShardStats};
pub use router::{Placement, Router, ShardLoad};

use replica::ShardMsg;

/// §Robustness: supervisor respawn backoff — capped exponential, per
/// shard, doubling on every death of that shard (a crash-looping backend
/// settles at one respawn attempt per [`RESPAWN_CAP_MS`]).
const RESPAWN_BASE_MS: u64 = 25;
const RESPAWN_CAP_MS: u64 = 2_000;

/// An admission shed tagged with the level that made it: `"global"` (the
/// router's fleet-wide budget) or `"shard"` (one engine's own budget).
/// The server surfaces the scope as a `"scope"` field on the shed line.
#[derive(Debug, Clone)]
pub struct ScopedShed {
    pub scope: &'static str,
    pub inner: AdmitError,
}

impl fmt::Display for ScopedShed {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)
    }
}

impl std::error::Error for ScopedShed {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        Some(&self.inner)
    }
}

/// A shard's engine died with work in flight — the jobs it was holding
/// are refused with this error (`"code": "shard_failed"` on the wire)
/// rather than silently dropped. Raised by a fatal pump error or an
/// injected [`Fleet::kill_shard`] crash; the rest of the fleet keeps
/// serving.
#[derive(Debug, Clone)]
pub struct ShardFailed {
    pub shard: usize,
    pub reason: String,
}

impl fmt::Display for ShardFailed {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "shard {} failed: {}", self.shard, self.reason)
    }
}

impl std::error::Error for ShardFailed {}

/// A request the client pulled back with `{"cmd":"cancel","id":..}` —
/// its pending reply is answered with this error (`"code": "canceled"`
/// on the wire) after the shard engine tore the work down and refunded
/// the admission/quota charges.
#[derive(Debug, Clone, Copy)]
pub struct Canceled {
    pub id: u64,
}

impl fmt::Display for Canceled {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "request {} canceled by the client", self.id)
    }
}

impl std::error::Error for Canceled {}

/// A submitted request's fleet-side address: the id the fleet assigned
/// (echoed on every reply line) and the shard it was placed on — what
/// [`Fleet::cancel`] needs to route a wire-level cancellation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Ticket {
    pub id: u64,
    pub shard: usize,
}

/// Routing-level refusals that are not admission sheds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteError {
    /// `{"cmd": "drain"}` has run (or is running): no new admissions.
    Draining,
    /// Every shard is gone (all dead, or the fleet was shut down).
    Closed,
}

impl fmt::Display for RouteError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RouteError::Draining => {
                write!(f, "server is draining: not admitting new requests")
            }
            RouteError::Closed => write!(f, "engine fleet is shut down"),
        }
    }
}

impl std::error::Error for RouteError {}

/// Fleet topology + budgets (`agd serve --shards/--placement/...`).
#[derive(Debug, Clone, Copy)]
pub struct FleetConfig {
    /// Engine replicas (`--shards`; min 1).
    pub shards: usize,
    /// Request placement discipline (`--placement`).
    pub placement: Placement,
    /// Scheduling discipline inside every shard (`--scheduler`).
    pub scheduler: SchedulerKind,
    /// Fleet-global budgets, checked at the router (`--max-in-flight`,
    /// `--max-queued-nfes`). Its `max_in_flight_per_client` member is
    /// ignored here — the per-client quota is shard-side.
    pub global_admission: Admission,
    /// Per-shard engine budgets (`--shard-max-in-flight`,
    /// `--shard-max-queued-nfes`), plus the per-client quota.
    pub shard_admission: Admission,
    /// Worker lanes per shard (`--workers`); 0 = available parallelism
    /// divided by the shard count (each shard owns its own pool).
    pub workers: usize,
    /// Shed deadline-infeasible requests at shard admission
    /// (`--shed-infeasible`).
    pub shed_infeasible: bool,
    /// §Robustness: per-pump transient-error retry budget inside every
    /// shard engine (`--max-batch-retries`; 0 = every backend error is
    /// fatal on first sight, the historical behaviour).
    pub max_batch_retries: usize,
    /// §Robustness: respawn dead shards via the stored backend factory
    /// (`--shard-respawn`), with capped exponential backoff.
    pub respawn: bool,
    /// §Robustness: checkpoint every N completed denoising steps per
    /// request (`--checkpoint-steps`; 0 = off — byte- and
    /// allocation-identical to a fleet without the feature). Armed, a
    /// dying shard hands started requests back with their latest
    /// snapshot and survivors resume them mid-trajectory.
    pub checkpoint_steps: usize,
}

impl Default for FleetConfig {
    fn default() -> FleetConfig {
        FleetConfig {
            shards: 1,
            placement: Placement::LeastLoaded,
            scheduler: SchedulerKind::Fifo,
            global_admission: Admission::unlimited(),
            shard_admission: Admission::unlimited(),
            workers: 1,
            shed_infeasible: false,
            max_batch_retries: 0,
            respawn: false,
            checkpoint_steps: 0,
        }
    }
}

/// The mutable router half: placement state + the shard channels.
/// One mutex guards both — placement, reservation and send happen as one
/// atomic step, which is what makes least-loaded deterministic under
/// concurrent submitters (and keeps `Fleet: Sync` on toolchains where
/// `mpsc::Sender` is not).
struct RouterInner {
    router: Router,
    txs: Vec<std::sync::mpsc::Sender<ShardMsg>>,
}

/// §Robustness: what a dying shard tells the supervisor thread.
pub(crate) enum SuperMsg {
    /// A shard ran its death path. `salvaged` carries every admitted job
    /// the engine could hand back: never-started jobs (`first_exec`
    /// unset, restarted from step 0 with the same init noise) and — with
    /// `--checkpoint-steps` — started jobs with their latest
    /// [`crate::coordinator::checkpoint::RequestCheckpoint`], resumed at
    /// the recorded step. The supervisor re-places them onto survivors;
    /// either way they complete byte-identically.
    Died { shard: usize, salvaged: Vec<Job> },
    /// Fleet shutdown: stop supervising and exit the thread.
    Shutdown,
}

/// State shared between the fleet handle, its shard threads, and the
/// supervisor thread (which must re-place salvaged jobs and swap a
/// respawned shard's channel without holding a `&Fleet`).
struct Shared {
    loads: Vec<Arc<ShardLoad>>,
    router: Mutex<RouterInner>,
    /// Fleet-level counters that belong to no shard engine: connection
    /// hygiene (`conn_*`, incremented by the server's handlers), chaos
    /// injections (`chaos_*`), and the supervisor's survival ledger
    /// (`jobs_salvaged_total`, `shard_respawned_total`). Merged into
    /// `{"cmd": "stats"}` / `{"cmd": "metrics"}` alongside the shard
    /// registries.
    telemetry: Mutex<Telemetry>,
    draining: AtomicBool,
    joins: Mutex<Vec<JoinHandle<()>>>,
}

/// Spawns one shard's engine thread; retained by the supervisor so dead
/// shards can be respawned with the same factory, config and seeds. The
/// `bool` is the warm-up flag: `true` on supervisor respawns (§Robustness
/// satellite — one synthetic eval before the shard rejoins placement, so
/// the first real request doesn't eat cold-start latency), `false` at
/// launch (the historical behaviour, and what keeps launch fast).
type Spawner = Box<dyn Fn(usize, Receiver<ShardMsg>, bool) -> JoinHandle<()> + Send>;

/// The engine fleet (see module docs). Shared across connection-handler
/// threads behind an `Arc`; every public method takes `&self`.
pub struct Fleet {
    shared: Arc<Shared>,
    global: Admission,
    placement: Placement,
    scheduler: SchedulerKind,
    next_id: AtomicU64,
    /// Launch instant — `uptime_s` in `{"cmd": "stats"}`.
    started: Instant,
    /// Supervisor mailbox (Mutex: `mpsc::Sender` is not `Sync` on every
    /// supported toolchain, and this is far off the hot path).
    super_tx: Mutex<Sender<SuperMsg>>,
    /// §Robustness: the fault plan armed into every shard's
    /// [`crate::chaos::FaultyBackend`] wrapper, when the server installed
    /// one (`--fault-spec`); the chaos director's `fault` op re-arms it
    /// live through [`Fleet::fault_plan`].
    fault_plan: Mutex<Option<Arc<FaultPlan>>>,
}

impl Fleet {
    /// Spawn `cfg.shards` engine threads, each constructing its own
    /// backend via `factory(shard_index)` *inside* the thread (the PJRT
    /// client must be born where it runs; the index is the hook for
    /// one-device-per-shard deployments). A shard whose construction
    /// fails is marked dead and skipped by placement — the fleet serves
    /// on the survivors, and [`Fleet::submit`] errors only when every
    /// shard is dead.
    pub fn launch<B, F>(factory: F, cfg: FleetConfig) -> Fleet
    where
        B: Backend + 'static,
        F: Fn(usize) -> Result<B> + Send + Sync + 'static,
    {
        let n = cfg.shards.max(1);
        let workers = if cfg.workers == 0 {
            (crate::exec::default_workers() / n).max(1)
        } else {
            cfg.workers
        };
        let factory = Arc::new(factory);
        let loads: Vec<Arc<ShardLoad>> = (0..n).map(|_| Arc::new(ShardLoad::default())).collect();
        let (super_tx, super_rx) = channel::<SuperMsg>();
        // the spawner is retained by the supervisor: a respawned shard is
        // built by the *same* closure as the original (same factory, same
        // scheduler/admission config, same per-shard retry seed), so a
        // respawn restores exactly the topology that launched
        let spawner: Spawner = {
            let loads = loads.clone();
            let super_tx = super_tx.clone();
            let (kind, adm, shed) = (cfg.scheduler, cfg.shard_admission, cfg.shed_infeasible);
            let retries = cfg.max_batch_retries;
            let ckpt_every = cfg.checkpoint_steps;
            Box::new(move |i: usize, rx: Receiver<ShardMsg>, warm: bool| {
                let f = factory.clone();
                let l = loads[i].clone();
                let stx = super_tx.clone();
                std::thread::Builder::new()
                    .name(format!("agd-shard-{i}"))
                    .spawn(move || {
                        let engine =
                            f(i).and_then(|be| Engine::with_scheduler(be, kind.build(), adm));
                        match engine {
                            Ok(mut engine) => {
                                engine.set_workers(workers);
                                engine.set_batch_retries(
                                    retries,
                                    DEFAULT_RETRY_BASE_MS,
                                    DEFAULT_RETRY_CAP_MS,
                                    i as u64,
                                );
                                engine.set_checkpoints(ckpt_every);
                                if warm {
                                    warm_up(&mut engine, i);
                                }
                                replica::run_replica(i, engine, rx, l, shed, stx);
                            }
                            Err(e) => {
                                // construction failures are permanent: the
                                // supervisor is not told, because respawning
                                // a backend that cannot be built would only
                                // crash-loop (vs. a *runtime* death, whose
                                // next construction may well succeed)
                                log_event(
                                    log::Level::Error,
                                    &format!("shard-{i}"),
                                    &format!("backend construction failed, marking dead: {e:#}"),
                                );
                                l.mark_dead();
                            }
                        }
                    })
                    .expect("spawn shard thread")
            })
        };
        let mut txs = Vec::with_capacity(n);
        let mut joins = Vec::with_capacity(n);
        for i in 0..n {
            let (tx, rx) = channel::<ShardMsg>();
            joins.push(spawner(i, rx, false));
            txs.push(tx);
        }
        let shared = Arc::new(Shared {
            loads,
            router: Mutex::new(RouterInner {
                router: Router::new(cfg.placement),
                txs,
            }),
            telemetry: Mutex::new(Telemetry::new()),
            draining: AtomicBool::new(false),
            joins: Mutex::new(joins),
        });
        {
            let sup_shared = shared.clone();
            let respawn = cfg.respawn;
            let sup = std::thread::Builder::new()
                .name("agd-supervisor".into())
                .spawn(move || supervise(&sup_shared, spawner, super_rx, respawn))
                .expect("spawn supervisor thread");
            shared.joins.lock().expect("joins lock").push(sup);
        }
        Fleet {
            shared,
            global: cfg.global_admission,
            placement: cfg.placement,
            scheduler: cfg.scheduler,
            next_id: AtomicU64::new(0),
            started: Instant::now(),
            super_tx: Mutex::new(super_tx),
            fault_plan: Mutex::new(None),
        }
    }

    /// §Robustness: install the fault plan the server armed into every
    /// shard's [`crate::chaos::FaultyBackend`], making it reachable by
    /// the chaos director's `fault` op.
    pub fn set_fault_plan(&self, plan: Arc<FaultPlan>) {
        *self.fault_plan.lock().expect("fault plan lock") = Some(plan);
    }

    /// The installed fault plan, if the server armed one.
    pub fn fault_plan(&self) -> Option<Arc<FaultPlan>> {
        self.fault_plan.lock().expect("fault plan lock").clone()
    }

    /// Is this shard currently placeable? (False while dead, true again
    /// once the supervisor respawns it — the chaos director's
    /// `wait-respawn` op polls this.)
    pub fn shard_alive(&self, shard: usize) -> bool {
        self.shared
            .loads
            .get(shard)
            .map(|l| !l.is_dead())
            .unwrap_or(false)
    }

    /// Bump a fleet-level counter (connection hygiene, chaos injections).
    /// Fleet-level because dead shards are skipped by stats collection —
    /// a counter living in a dying engine's registry would never be
    /// scraped again.
    pub fn count(&self, name: &str, labels: &[(&str, &str)]) {
        self.shared
            .telemetry
            .lock()
            .expect("fleet telemetry lock")
            .inc(name, labels, 1);
    }

    /// Inject a crash into a live shard — the chaos harness's fault hook
    /// ([`crate::chaos::Director`]'s `kill-shard` op). The shard runs its
    /// real fatal path between batch steps: in-flight jobs are refused
    /// with `"code": "shard_failed"` and the shard is marked dead, while
    /// the rest of the fleet keeps serving. Returns `false` when the
    /// index is out of range or the shard is already dead. Jobs placed
    /// before this call are guaranteed to reach the shard first (one
    /// FIFO channel per shard), so a mid-flight kill always exercises the
    /// refusal path, never a silent drop.
    pub fn kill_shard(&self, shard: usize) -> bool {
        {
            let guard = self.shared.router.lock().expect("router lock");
            if shard >= self.shared.loads.len() || self.shared.loads[shard].is_dead() {
                return false;
            }
            if guard.txs[shard].send(ShardMsg::Crash).is_err() {
                // channel gone without a death mark (shutdown race)
                self.shared.loads[shard].mark_dead();
                return false;
            }
        }
        let label = shard.to_string();
        self.count("chaos_kill_shard_total", &[("shard", &label)]);
        true
    }

    pub fn shards(&self) -> usize {
        self.shared.loads.len()
    }

    pub fn placement(&self) -> Placement {
        self.placement
    }

    pub fn is_draining(&self) -> bool {
        self.shared.draining.load(Ordering::SeqCst)
    }

    /// Fleet-wide request count (live shards only; reservations included).
    fn total_requests(&self) -> usize {
        self.shared
            .loads
            .iter()
            .filter(|l| !l.is_dead())
            .map(|l| l.requests())
            .sum()
    }

    /// Fleet-wide queued-NFE estimate (live shards only).
    fn total_nfes(&self) -> usize {
        self.shared
            .loads
            .iter()
            .filter(|l| !l.is_dead())
            .map(|l| l.nfes())
            .sum()
    }

    /// Route one request: global admission → placement → reservation →
    /// shard channel. Returns the reply channel the shard will answer on
    /// ([`JobReply::Done`] with the bit-exact [`Completion`], or
    /// [`JobReply::Error`] with the protocol line). Errors here are
    /// router-level: [`RouteError::Draining`]/[`RouteError::Closed`] or a
    /// global-scope [`ScopedShed`].
    pub fn submit(&self, req: Request) -> Result<Receiver<JobReply>> {
        let (rtx, rrx) = channel();
        self.submit_to(req, ReplyTo::Channel(rtx))?;
        Ok(rrx)
    }

    /// [`Self::submit`] for front-ends that cannot block on a channel: the
    /// caller supplies the reply sink (§Scale: the reactor hands in a
    /// push-and-wake [`ReplyTarget`]) and gets back the [`Ticket`] naming
    /// the fleet-assigned id and the shard the request landed on — the
    /// address a later [`Self::cancel`] routes to.
    pub fn submit_to(&self, mut req: Request, reply: ReplyTo) -> Result<Ticket> {
        // §Observability: the admission and placement stage durations are
        // stamped onto traced requests; the shard engine reconstructs
        // start times from them (the queue stage is stamped shard-side)
        let t_admit = Instant::now();
        // worst-case cost, for the global budget and the reservation; a
        // step count the engine would refuse anyway reserves nothing (and
        // skips the O(steps) plan walk on the router thread)
        let cost = if req.steps >= 1 && req.steps <= MAX_STEPS {
            req.policy.max_nfes(req.steps)
        } else {
            0
        };
        let mut guard = self.shared.router.lock().expect("router lock");
        if self.is_draining() {
            return Err(anyhow::Error::new(RouteError::Draining));
        }
        if let Err(inner) = self
            .global
            .check(self.total_requests(), self.total_nfes(), cost)
        {
            return Err(anyhow::Error::new(ScopedShed {
                scope: "global",
                inner,
            }));
        }
        let t_place = Instant::now();
        let Some(idx) = guard.router.place(&self.shared.loads, req.client_id.as_deref()) else {
            return Err(anyhow::Error::new(RouteError::Closed));
        };
        if req.trace {
            req.span_admission_us =
                t_place.saturating_duration_since(t_admit).as_micros() as u64;
            req.span_placement_us = t_place.elapsed().as_micros() as u64;
        }
        req.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let id = req.id;
        let load = &self.shared.loads[idx];
        load.reserve(cost);
        let job = Job {
            req,
            cost,
            started: Instant::now(),
            reply,
            checkpoint: None,
        };
        if guard.txs[idx].send(ShardMsg::Job(job)).is_err() {
            load.settle(cost);
            load.mark_dead();
            return Err(anyhow::Error::new(RouteError::Closed));
        }
        Ok(Ticket { id, shard: idx })
    }

    /// Wire-level cancellation: ask the ticket's shard to pull the request
    /// back out of its engine ([`ShardMsg::Cancel`]). Fire-and-forget —
    /// the outcome arrives on the request's own reply sink (a structured
    /// `"code": "canceled"` line when the cancel won, the completion when
    /// it lost the race). Returns `false` when the shard is gone (dead or
    /// respawning — its jobs were already refused or salvaged elsewhere,
    /// so there is nothing left to cancel). The shard channel is FIFO, so
    /// a cancel can never overtake its own job. A supervisor re-placement
    /// after shard death may move the request to a different shard than
    /// the ticket names; a cancel issued across that window misses — an
    /// accepted, observable race (the request simply completes).
    pub fn cancel(&self, t: Ticket) -> bool {
        let guard = self.shared.router.lock().expect("router lock");
        if t.shard >= guard.txs.len() || self.shared.loads[t.shard].is_dead() {
            return false;
        }
        guard.txs[t.shard].send(ShardMsg::Cancel(t.id)).is_ok()
    }

    /// Clone the shard channels out of the router lock, so slow follow-up
    /// work (waiting on stats/drain acks) never blocks placement.
    fn channels(&self) -> Vec<std::sync::mpsc::Sender<ShardMsg>> {
        self.shared.router.lock().expect("router lock").txs.clone()
    }

    /// Collect every live shard's stats snapshot.
    fn collect(&self) -> Result<Vec<ShardStats>> {
        let mut rxs = Vec::new();
        for (tx, load) in self.channels().iter().zip(&self.shared.loads) {
            if load.is_dead() {
                continue;
            }
            let (rtx, rx) = channel();
            if tx.send(ShardMsg::Stats(rtx)).is_ok() {
                rxs.push(rx);
            }
        }
        let stats: Vec<ShardStats> = rxs.into_iter().filter_map(|rx| rx.recv().ok()).collect();
        anyhow::ensure!(!stats.is_empty(), "engine fleet is shut down");
        Ok(stats)
    }

    /// §Observability: drain every live shard's span ring
    /// (`{"cmd": "spans"}`). Each batch arrives stamped with its shard id;
    /// serialize with [`crate::trace::batches_to_json`].
    pub fn drain_spans(&self) -> Result<Vec<crate::trace::SpanBatch>> {
        let mut rxs = Vec::new();
        for (tx, load) in self.channels().iter().zip(&self.shared.loads) {
            if load.is_dead() {
                continue;
            }
            let (rtx, rx) = channel();
            if tx.send(ShardMsg::Spans(rtx)).is_ok() {
                rxs.push(rx);
            }
        }
        let batches: Vec<crate::trace::SpanBatch> =
            rxs.into_iter().filter_map(|rx| rx.recv().ok()).collect();
        anyhow::ensure!(!batches.is_empty(), "engine fleet is shut down");
        Ok(batches)
    }

    /// Merge shard registries: fleet totals (unlabelled) + per-shard
    /// series under `shard=` labels, plus the fleet topology gauges.
    /// Gauges exist only under their `shard=` label (intensive gauges
    /// have no meaningful sum — see [`Telemetry::absorb`]); the extensive
    /// fleet totals are published here from the scalar snapshots.
    fn merged_telemetry(&self, stats: &[ShardStats]) -> Telemetry {
        let mut merged = Telemetry::new();
        for st in stats {
            merged.absorb(&st.telemetry, None);
        }
        for st in stats {
            let shard = st.shard.to_string();
            merged.absorb(&st.telemetry, Some(("shard", &shard)));
        }
        // fleet-level counters (conn_*, chaos_*, salvage/respawn) ride
        // along unlabelled
        {
            let own = self.shared.telemetry.lock().expect("fleet telemetry lock");
            merged.absorb(&own, None);
        }
        // dead shards answer no Stats message, so deaths are counted here
        // from the load's persistent ledger instead of a registry nobody
        // can scrape — and the ledger survives a supervisor respawn, so a
        // shard that died twice and came back twice still reports 2
        for (i, load) in self.shared.loads.iter().enumerate() {
            let died = load.died();
            if died > 0 {
                let shard = i.to_string();
                merged.inc("shard_died_total", &[("shard", &shard)], died);
            }
        }
        let sum = |f: &dyn Fn(&ShardStats) -> usize| stats.iter().map(f).sum::<usize>() as f64;
        merged.set_gauge("active_requests", &[], sum(&|t| t.active));
        merged.set_gauge("queue_depth", &[], sum(&|t| t.queue_depth));
        merged.set_gauge("queued_nfes", &[], sum(&|t| t.queued_nfes));
        merged.set_gauge("fleet_shards", &[], self.shared.loads.len() as f64);
        merged.set_gauge(
            "fleet_shards_alive",
            &[],
            self.shared.loads.iter().filter(|l| !l.is_dead()).count() as f64,
        );
        merged
    }

    /// `{"cmd": "stats"}`: fleet totals, per-shard breakdown, and the
    /// merged telemetry registry.
    pub fn stats_json(&self) -> Result<Value> {
        use crate::util::json::{arr, num, obj, s};
        let stats = self.collect()?;
        let sum = |f: &dyn Fn(&ShardStats) -> usize| stats.iter().map(f).sum::<usize>();
        let (batches, items) = (sum(&|t| t.batches), sum(&|t| t.items));
        let spans_dropped: u64 = stats.iter().map(|t| t.spans_dropped).sum();
        let per_shard: Vec<Value> = stats
            .iter()
            .map(|t| {
                obj(vec![
                    ("shard", num(t.shard as f64)),
                    ("active", num(t.active as f64)),
                    ("queue_depth", num(t.queue_depth as f64)),
                    ("queued_nfes", num(t.queued_nfes as f64)),
                    ("batches", num(t.batches as f64)),
                    ("items", num(t.items as f64)),
                    ("mean_occupancy", num(t.mean_occupancy)),
                    ("spans_dropped_total", num(t.spans_dropped as f64)),
                ])
            })
            .collect();
        let telemetry = self.merged_telemetry(&stats);
        Ok(obj(vec![
            ("scheduler", s(self.scheduler.name())),
            ("version", s(env!("CARGO_PKG_VERSION"))),
            ("uptime_s", num(self.started.elapsed().as_secs_f64())),
            ("shards", num(self.shared.loads.len() as f64)),
            ("placement", s(self.placement().name())),
            ("draining", json::Value::Bool(self.is_draining())),
            ("active", num(sum(&|t| t.active) as f64)),
            ("queue_depth", num(sum(&|t| t.queue_depth) as f64)),
            ("queued_nfes", num(sum(&|t| t.queued_nfes) as f64)),
            ("batches", num(batches as f64)),
            ("items", num(items as f64)),
            (
                "mean_occupancy",
                num(if batches == 0 {
                    0.0
                } else {
                    items as f64 / batches as f64
                }),
            ),
            ("spans_dropped_total", num(spans_dropped as f64)),
            ("per_shard", arr(per_shard)),
            ("telemetry", telemetry.to_json()),
        ]))
    }

    /// `{"cmd": "metrics"}`: Prometheus exposition of the merged registry
    /// (fleet totals + `shard=`-labelled per-shard series).
    pub fn metrics_prometheus(&self) -> Result<String> {
        let stats = self.collect()?;
        Ok(self.merged_telemetry(&stats).to_prometheus())
    }

    /// Stop admitting (subsequent submits get a `draining` error) and
    /// block until every shard is idle. In-flight work always completes —
    /// each shard acknowledges only once its engine has nothing queued or
    /// executing. Idempotent; returns the shard count.
    pub fn drain(&self) -> usize {
        {
            // serialize with in-progress submits: a request that won the
            // router lock before us reaches its shard's channel ahead of
            // the Drain message and is therefore waited for
            let _guard = self.shared.router.lock().expect("router lock");
            self.shared.draining.store(true, Ordering::SeqCst);
        }
        let mut acks = Vec::new();
        for tx in self.channels() {
            let (rtx, rx) = channel();
            if tx.send(ShardMsg::Drain(rtx)).is_ok() {
                acks.push(rx);
            }
        }
        for rx in acks {
            let _ = rx.recv();
        }
        self.shared.loads.len()
    }

    /// Drain, then join every engine thread. The graceful teardown path —
    /// wired into `{"cmd": "drain"}`-driven shutdown and used by tests to
    /// close a fleet without leaking threads. Idempotent.
    pub fn shutdown(&self) -> usize {
        let n = self.drain();
        for tx in self.channels() {
            let _ = tx.send(ShardMsg::Shutdown);
        }
        // stop the supervisor too — it sits in the same join set, and a
        // respawn racing shutdown is harmless (the fresh shard idles and
        // exits on its channel closing); drain already set the flag that
        // stops further respawns
        {
            let tx = self.super_tx.lock().expect("supervisor tx lock");
            let _ = tx.send(SuperMsg::Shutdown);
        }
        let mut joins = self.shared.joins.lock().expect("joins lock");
        for j in joins.drain(..) {
            let _ = j.join();
        }
        n
    }
}

/// §Robustness: warm a respawned shard before it rejoins placement — one
/// synthetic single-row eval through the backend's real batch path, off
/// the serving hot path (the shard is still dead to the router while this
/// runs, because [`supervise`] revives the load only after the thread is
/// spawned *and* the channel is swapped in; the warm-up runs first thing
/// inside the thread, before the replica loop can pick anything up). A
/// GMM backend warms its lane scratch; a PJRT backend touches its
/// compiled artifact so the first real request doesn't pay cold-start
/// latency. Failures are deliberately ignored: a backend that faults on
/// the warm-up row (e.g. a still-armed fault plan) will fault on real
/// work too, and the death path handles that — the warm-up must never
/// turn a respawn into a construction failure. Duration is published as
/// the `shard_warmup_ms` gauge on the shard's own registry.
fn warm_up<B: Backend>(engine: &mut Engine<B>, shard: usize) {
    let t0 = Instant::now();
    let flat_in = engine.backend.flat_in("gmm");
    let mut batch = BatchBuf::new(flat_in, 4);
    let (x, tokens) = batch.push_row(0.5);
    x.fill(0.1);
    tokens.fill(0); // unconditional row: valid for every token vocabulary
    let mut out = BatchOut::default();
    let _ = engine.backend.denoise_into("gmm", &batch, &mut out);
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    engine.telemetry_mut().set_gauge("shard_warmup_ms", &[], ms);
    log_event(
        log::Level::Info,
        &format!("shard-{shard}"),
        &format!("respawn warm-up eval ran in {ms:.3}ms"),
    );
}

/// §Robustness: the supervisor loop. Two duties per shard death: re-place
/// the salvaged (never-started) jobs onto survivors, and — when
/// `--shard-respawn` is on and the fleet is not draining — rebuild the
/// dead shard via the retained [`Spawner`] after a capped exponential
/// backoff, swap its channel in under the router lock, and revive its
/// load so placement sees it again.
fn supervise(shared: &Shared, spawner: Spawner, rx: Receiver<SuperMsg>, respawn: bool) {
    let mut backoff: Vec<u64> = vec![RESPAWN_BASE_MS; shared.loads.len()];
    while let Ok(msg) = rx.recv() {
        match msg {
            SuperMsg::Died { shard, salvaged } => {
                if !salvaged.is_empty() {
                    replace_jobs(shared, shard, salvaged);
                }
                if respawn && !shared.draining.load(Ordering::SeqCst) {
                    let delay = backoff[shard];
                    backoff[shard] = (delay * 2).min(RESPAWN_CAP_MS);
                    log_event(
                        log::Level::Warn,
                        "supervisor",
                        &format!("shard {shard} died; respawning after {delay}ms backoff"),
                    );
                    std::thread::sleep(Duration::from_millis(delay));
                    let (tx, shard_rx) = channel::<ShardMsg>();
                    let join = spawner(shard, shard_rx, true);
                    {
                        // swap the channel in *before* reviving: from the
                        // moment placement sees the shard alive, its sends
                        // reach the fresh thread
                        let mut guard = shared.router.lock().expect("router lock");
                        guard.txs[shard] = tx;
                    }
                    shared.loads[shard].revive();
                    shared.joins.lock().expect("joins lock").push(join);
                    let label = shard.to_string();
                    shared
                        .telemetry
                        .lock()
                        .expect("fleet telemetry lock")
                        .inc("shard_respawned_total", &[("shard", &label)], 1);
                    log_event(
                        log::Level::Info,
                        "supervisor",
                        &format!("shard {shard} respawned and serving"),
                    );
                }
            }
            SuperMsg::Shutdown => return,
        }
    }
}

/// Re-place one dead shard's salvaged jobs onto survivors. The jobs keep
/// their fleet-assigned request ids and skip global admission — they were
/// already admitted once, and shedding previously-accepted work to a
/// budget check would turn a survivable fault into a refusal. A job only
/// sheds (`shard_failed`) when no live shard remains to take it.
/// Never-started jobs tick `jobs_salvaged_total{shard=}` (the PR 8
/// ledger); checkpointed mid-flight jobs tick `jobs_resumed_total{shard=}`
/// and record their resume step in the `resume_step` histogram, so an
/// operator can see how deep into trajectories the fleet is recovering.
fn replace_jobs(shared: &Shared, from: usize, jobs: Vec<Job>) {
    let total = jobs.len();
    let mut placed = 0usize;
    let mut resumed = 0u64;
    let mut resume_steps: Vec<f64> = Vec::new();
    for job in jobs {
        let mut job = Some(job);
        loop {
            let mut guard = shared.router.lock().expect("router lock");
            let j = job.take().expect("job to place");
            let Some(idx) = guard.router.place(&shared.loads, j.req.client_id.as_deref()) else {
                let e = anyhow::Error::new(ShardFailed {
                    shard: from,
                    reason: "shard died before execution; no live shard left to salvage onto"
                        .into(),
                });
                j.reply.send(JobReply::Error(crate::server::error_to_line(&e)));
                break;
            };
            let cost = j.cost;
            shared.loads[idx].reserve(cost);
            let resume_step = j.checkpoint.as_ref().map(|ck| ck.step);
            match guard.txs[idx].send(ShardMsg::Job(j)) {
                Ok(()) => {
                    placed += 1;
                    if let Some(step) = resume_step {
                        resumed += 1;
                        resume_steps.push(step as f64);
                    }
                    break;
                }
                Err(std::sync::mpsc::SendError(msg)) => {
                    // raced another shard's death: roll back, mark, retry
                    shared.loads[idx].settle(cost);
                    shared.loads[idx].mark_dead();
                    match msg {
                        ShardMsg::Job(back) => job = Some(back),
                        _ => unreachable!("sent a job, got back something else"),
                    }
                }
            }
        }
    }
    let label = from.to_string();
    {
        let mut tel = shared.telemetry.lock().expect("fleet telemetry lock");
        let unstarted = placed as u64 - resumed;
        tel.inc("jobs_salvaged_total", &[("shard", &label)], unstarted);
        if resumed > 0 {
            tel.inc("jobs_resumed_total", &[("shard", &label)], resumed);
            for step in &resume_steps {
                // same shape every shard, so the fleet histogram merges
                tel.observe("resume_step", &[], *step, 0.0, 200.0, 40);
            }
        }
    }
    log_event(
        log::Level::Warn,
        "supervisor",
        &format!(
            "shard {from}: salvaged {placed}/{total} job(s) onto survivors ({resumed} resuming mid-flight)"
        ),
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::GmmBackend;
    use crate::coordinator::policy::cfg;
    use crate::sim::gmm::Gmm;

    fn fleet(n: usize, placement: Placement) -> Fleet {
        Fleet::launch(
            |_shard| Ok(GmmBackend::new(Gmm::axes(8, 4, 3.0, 0.05))),
            FleetConfig {
                shards: n,
                placement,
                ..FleetConfig::default()
            },
        )
    }

    fn req(comp: i32, steps: usize) -> Request {
        // ids are fleet-assigned; the 0 here is overwritten at submit
        Request::new(0, "gmm", vec![comp, 0, 0, 0], 100 + comp as u64, steps, cfg(2.0))
    }

    #[test]
    fn fleet_serves_and_shuts_down() {
        let fleet = fleet(2, Placement::RoundRobin);
        let rxs: Vec<_> = (0..4).map(|i| fleet.submit(req(1 + i % 4, 6)).unwrap()).collect();
        for rx in rxs {
            match rx.recv().unwrap() {
                JobReply::Done(c, ms) => {
                    assert_eq!(c.nfes, 12);
                    assert!(ms >= 0.0);
                }
                JobReply::Error(line) => panic!("unexpected error: {line}"),
                JobReply::Progress(n) => panic!("unexpected progress: {n:?}"),
            }
        }
        let stats = fleet.stats_json().unwrap();
        assert_eq!(stats.req("shards").as_f64(), Some(2.0));
        assert_eq!(stats.req("active").as_f64(), Some(0.0));
        assert_eq!(stats.req("placement").as_str(), Some("round-robin"));
        // both shards saw work under round-robin
        let per = stats.req("per_shard").as_arr().unwrap();
        assert_eq!(per.len(), 2);
        assert!(per.iter().all(|s| s.req("items").as_f64().unwrap() > 0.0));
        // prometheus carries fleet totals and shard-labelled series
        let prom = fleet.metrics_prometheus().unwrap();
        assert!(prom.contains("fleet_shards 2"), "{prom}");
        assert!(prom.contains("shard=\"0\""), "{prom}");
        assert!(prom.contains("shard=\"1\""), "{prom}");

        assert_eq!(fleet.shutdown(), 2);
        // post-shutdown: draining error, stats unavailable
        let err = fleet.submit(req(1, 4)).unwrap_err();
        assert!(err.downcast_ref::<RouteError>() == Some(&RouteError::Draining), "{err}");
        assert!(fleet.stats_json().is_err());
        // idempotent
        assert_eq!(fleet.shutdown(), 2);
    }

    #[test]
    fn traced_requests_span_the_fleet_and_stats_carry_uptime() {
        let fleet = fleet(2, Placement::RoundRobin);
        let rxs: Vec<_> = (0..4)
            .map(|i| {
                let mut r = req(1 + i % 4, 6);
                r.trace = true;
                fleet.submit(r).unwrap()
            })
            .collect();
        for rx in rxs {
            match rx.recv().unwrap() {
                JobReply::Done(c, _) => {
                    let tl = c.timeline.as_ref().expect("traced timeline");
                    let rows = tl.as_arr().unwrap();
                    // every lifecycle stage appears, including the three
                    // front-end stages the fleet stamped
                    for stage in crate::trace::Stage::ALL {
                        assert!(
                            rows.iter().any(|v| v.req("type").as_str() == Some("span")
                                && v.req("stage").as_str() == Some(stage.name())),
                            "missing {} in {tl:?}",
                            stage.name()
                        );
                    }
                }
                JobReply::Error(line) => panic!("{line}"),
                JobReply::Progress(n) => panic!("unexpected progress: {n:?}"),
            }
        }
        // spans drained per shard, stamped with their shard ids
        let batches = fleet.drain_spans().unwrap();
        assert_eq!(batches.len(), 2);
        let shards: Vec<usize> = batches.iter().map(|b| b.shard).collect();
        assert!(shards.contains(&0) && shards.contains(&1), "{shards:?}");
        assert!(
            batches.iter().all(|b| !b.events.is_empty()),
            "round-robin put traced work on both shards"
        );
        // a second drain is empty (the rings cleared), drops still zero
        let again = fleet.drain_spans().unwrap();
        assert!(again.iter().all(|b| b.events.is_empty()));
        // the stats satellite: uptime, crate version, per-shard drops
        let stats = fleet.stats_json().unwrap();
        assert!(stats.req("uptime_s").as_f64().unwrap() >= 0.0);
        assert_eq!(
            stats.req("version").as_str(),
            Some(env!("CARGO_PKG_VERSION"))
        );
        assert_eq!(stats.req("spans_dropped_total").as_f64(), Some(0.0));
        for sh in stats.req("per_shard").as_arr().unwrap() {
            assert_eq!(sh.req("spans_dropped_total").as_f64(), Some(0.0));
        }
        fleet.shutdown();
        assert!(fleet.drain_spans().is_err(), "shut-down fleet has no rings");
    }

    /// A fleet whose every shard is a [`crate::chaos::FaultyBackend`]
    /// wrapper sharing `plans[i]` — the same wiring `agd serve
    /// --fault-spec` uses, with per-shard plans for targeted injection.
    fn faulty_fleet(plans: Vec<Arc<FaultPlan>>, cfg: FleetConfig) -> Fleet {
        use crate::chaos::fault::FaultyBackend;
        Fleet::launch(
            move |shard| {
                Ok(FaultyBackend::with_shard(
                    GmmBackend::new(Gmm::axes(8, 4, 3.0, 0.05)),
                    plans[shard].clone(),
                    shard as u64,
                ))
            },
            cfg,
        )
    }

    fn recv_done(rx: &std::sync::mpsc::Receiver<JobReply>) -> Box<crate::coordinator::request::Completion> {
        match rx.recv().unwrap() {
            JobReply::Done(c, _) => c,
            JobReply::Error(line) => panic!("unexpected error: {line}"),
            JobReply::Progress(n) => panic!("unexpected progress: {n:?}"),
        }
    }

    #[test]
    fn dead_shard_salvages_unstarted_jobs_to_survivors() {
        use crate::chaos::fault::{FaultPlan, FaultSpec};
        // shard 0 stalls 150ms inside its first batch; shard 1 is clean
        let plans: Vec<Arc<FaultPlan>> =
            (0..2).map(|_| Arc::new(FaultPlan::default())).collect();
        plans[0].arm(FaultSpec::parse("stall-at=1:150").unwrap());
        let fleet = faulty_fleet(
            plans,
            FleetConfig {
                shards: 2,
                placement: Placement::RoundRobin,
                ..FleetConfig::default()
            },
        );
        let rx0 = fleet.submit(req(1, 6)).unwrap(); // → shard 0, stalls mid-step
        std::thread::sleep(Duration::from_millis(50)); // let it start executing
        let rx1 = fleet.submit(req(2, 6)).unwrap(); // → shard 1, unaffected
        let rx2 = fleet.submit(req(3, 6)).unwrap(); // → shard 0, never starts
        assert!(fleet.kill_shard(0));
        // mid-step work on the victim sheds with the salvage summary…
        match rx0.recv().unwrap() {
            JobReply::Error(line) => {
                assert!(line.contains("shard_failed"), "{line}");
                assert!(line.contains("1 never-started job(s) salvaged"), "{line}");
            }
            JobReply::Done(..) => panic!("mid-step work must shed on a killed shard"),
            JobReply::Progress(n) => panic!("unexpected progress: {n:?}"),
        }
        // …while the never-started job completes on the survivor,
        // byte-identical to an undisturbed single-shard run
        let salvaged = recv_done(&rx2);
        let survivor = recv_done(&rx1);
        assert_eq!(survivor.nfes, 12);
        let clean = fleet2_free_run(req(3, 6));
        assert_eq!(salvaged.image, clean.image, "salvage leaked into the math");
        assert_eq!(salvaged.nfes, clean.nfes);
        // the survival ledger is visible in the merged stats (the counter
        // lands just after re-placement, so poll briefly)
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let stats = fleet.stats_json().unwrap();
            let tel = stats.req("telemetry");
            if tel.req("counters").get("jobs_salvaged_total{shard=0}").and_then(Value::as_f64)
                == Some(1.0)
            {
                assert_eq!(
                    tel.req("counters").req("shard_died_total{shard=0}").as_f64(),
                    Some(1.0)
                );
                break;
            }
            assert!(Instant::now() < deadline, "salvage counter never appeared");
            std::thread::sleep(Duration::from_millis(5));
        }
        fleet.shutdown();
    }

    #[test]
    fn checkpointed_mid_flight_jobs_resume_on_survivors() {
        use crate::chaos::fault::{FaultPlan, FaultSpec};
        let plans: Vec<Arc<FaultPlan>> =
            (0..2).map(|_| Arc::new(FaultPlan::default())).collect();
        // shard 0 completes exactly 2 batches (= 2 steps for a lone CFG
        // request: cond + uncond pack into one batch per step), then dies
        // fatally on the 3rd — fully deterministic, no timing involved
        plans[0].arm(FaultSpec::parse("fail-after=2").unwrap());
        let fleet = faulty_fleet(
            plans,
            FleetConfig {
                shards: 2,
                placement: Placement::RoundRobin,
                checkpoint_steps: 1,
                ..FleetConfig::default()
            },
        );
        let rx = fleet.submit(req(1, 6)).unwrap(); // → shard 0, dies mid-flight
        // the job is not refused: its checkpoint travels to shard 1,
        // which resumes at the recorded step and completes byte-identical
        // to an undisturbed run
        let done = recv_done(&rx);
        let clean = fleet2_free_run(req(1, 6));
        assert_eq!(done.image, clean.image, "resume leaked into the math");
        assert_eq!(done.nfes, clean.nfes);
        assert_eq!(done.cfg_steps, clean.cfg_steps);
        // ledger: counted as resumed, not as never-started salvage
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let stats = fleet.stats_json().unwrap();
            let tel = stats.req("telemetry");
            if tel
                .req("counters")
                .get("jobs_resumed_total{shard=0}")
                .and_then(Value::as_f64)
                == Some(1.0)
            {
                break;
            }
            assert!(Instant::now() < deadline, "resume counter never appeared");
            std::thread::sleep(Duration::from_millis(5));
        }
        fleet.shutdown();
    }

    /// One clean single-shard completion of `r`, for golden comparison.
    fn fleet2_free_run(r: Request) -> Box<crate::coordinator::request::Completion> {
        let f = fleet(1, Placement::LeastLoaded);
        let rx = f.submit(r).unwrap();
        let done = recv_done(&rx);
        f.shutdown();
        done
    }

    #[test]
    fn supervisor_respawns_a_killed_shard() {
        use crate::chaos::fault::FaultPlan;
        let plans = vec![Arc::new(FaultPlan::default())];
        let fleet = faulty_fleet(
            plans,
            FleetConfig {
                shards: 1,
                respawn: true,
                ..FleetConfig::default()
            },
        );
        let first = recv_done(&fleet.submit(req(1, 6)).unwrap());
        assert!(fleet.kill_shard(0));
        // the supervisor brings the shard back within its backoff window
        let deadline = Instant::now() + Duration::from_secs(5);
        while !fleet.shard_alive(0) {
            assert!(Instant::now() < deadline, "shard 0 never respawned");
            std::thread::sleep(Duration::from_millis(5));
        }
        // and the respawned shard serves byte-identical results
        let again = recv_done(&fleet.submit(req(1, 6)).unwrap());
        assert_eq!(again.image, first.image);
        assert_eq!(again.nfes, first.nfes);
        // the respawn counter lands just after the revive — poll briefly
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let stats = fleet.stats_json().unwrap();
            let tel = stats.req("telemetry");
            if tel
                .req("counters")
                .get("shard_respawned_total{shard=0}")
                .and_then(Value::as_f64)
                == Some(1.0)
            {
                // the death ledger survives the revive
                assert_eq!(
                    tel.req("counters").req("shard_died_total{shard=0}").as_f64(),
                    Some(1.0)
                );
                assert_eq!(tel.req("gauges").req("fleet_shards_alive").as_f64(), Some(1.0));
                break;
            }
            assert!(Instant::now() < deadline, "respawn counter never appeared");
            std::thread::sleep(Duration::from_millis(5));
        }
        fleet.shutdown();
    }

    #[test]
    fn without_respawn_a_dead_shard_stays_dead() {
        let fleet = fleet(2, Placement::RoundRobin);
        assert!(fleet.kill_shard(0));
        // killing is not instant — wait for the death to land
        let deadline = Instant::now() + Duration::from_secs(5);
        while fleet.shard_alive(0) {
            assert!(Instant::now() < deadline, "shard 0 never died");
            std::thread::sleep(Duration::from_millis(5));
        }
        std::thread::sleep(Duration::from_millis(60)); // would cover a respawn backoff
        assert!(!fleet.shard_alive(0), "respawn must be opt-in");
        // the survivor keeps serving
        let done = recv_done(&fleet.submit(req(1, 6)).unwrap());
        assert_eq!(done.nfes, 12);
        fleet.shutdown();
    }

    #[test]
    fn drain_blocks_new_work_but_finishes_old() {
        let fleet = fleet(2, Placement::LeastLoaded);
        let rx = fleet.submit(req(2, 12)).unwrap();
        assert_eq!(fleet.drain(), 2);
        // the in-flight request completed rather than being dropped
        match rx.recv().unwrap() {
            JobReply::Done(c, _) => assert_eq!(c.nfes, 24),
            JobReply::Error(line) => panic!("{line}"),
            JobReply::Progress(n) => panic!("unexpected progress: {n:?}"),
        }
        let err = fleet.submit(req(1, 4)).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<RouteError>(),
            Some(RouteError::Draining)
        ));
        // stats still answer while drained-but-not-joined
        assert!(fleet.stats_json().unwrap().req("draining").as_bool() == Some(true));
        fleet.shutdown();
    }
}
