//! # adaptive-guidance
//!
//! A full-system reproduction of *"Adaptive Guidance: Training-free
//! Acceleration of Conditional Diffusion Models"* (AAAI 2025) as a
//! three-layer Rust + JAX + Pallas serving stack:
//!
//! * **L3 (this crate)** — the serving coordinator: continuation batching of
//!   NFE work items, the guidance policy engine (CFG / AG / LINEARAG /
//!   searched / pix2pix), OLS fitting, the NAS search driver, metrics,
//!   quality + statistics substrates, and the CLI/server.
//! * **L2/L1 (`python/compile/`)** — the DiT denoiser and Pallas kernels,
//!   AOT-lowered once to HLO text and executed here via the PJRT C API
//!   (`runtime`). Python never runs on the request path.
//!
//! Start with [`coordinator::engine::Engine`] and
//! [`coordinator::policy::GuidancePolicy`]; see `examples/quickstart.rs`.

pub mod backend;
pub mod coordinator;
pub mod eval;
pub mod metrics;
pub mod ols;
pub mod perfstat;
pub mod prompts;
pub mod quality;
pub mod render;
pub mod runtime;
pub mod search;
pub mod server;
pub mod sim;
pub mod stats;
pub mod tensor;
pub mod testing;
pub mod util;

pub use backend::{Backend, EvalInput, GmmBackend};
pub use coordinator::engine::Engine;
pub use coordinator::policy::GuidancePolicy;
pub use coordinator::request::{Completion, Request};
