//! # adaptive-guidance
//!
//! A full-system reproduction of *"Adaptive Guidance: Training-free
//! Acceleration of Conditional Diffusion Models"* (AAAI 2025) as a
//! three-layer Rust + JAX + Pallas serving stack:
//!
//! * **L3 (this crate)** — the serving coordinator: continuation batching of
//!   NFE work items, the open guidance-policy API, OLS fitting, the NAS
//!   search driver, metrics, quality + statistics substrates, and the
//!   CLI/server.
//! * **L2/L1 (`python/compile/`)** — the DiT denoiser and Pallas kernels,
//!   AOT-lowered once to HLO text and executed here via the PJRT C API
//!   (`runtime`). Python never runs on the request path.
//!
//! ## The policy API
//!
//! Guidance policies implement the [`Policy`] trait
//! ([`coordinator::policy`]): `plan(step, total, &state)` chooses the
//! network evaluations for a step, `observe(&mut state, obs)` reacts to the
//! gamma convergence signal, and all per-request adaptive state lives in a
//! [`PolicyState`] owned by the request — so policies can carry gamma
//! histories, counters, or adaptive scales without engine support.
//!
//! Policies are constructed by name through [`PolicyRegistry`] from the
//! [`PolicySpec`] wire format ([`coordinator::spec`]), which the server
//! line protocol, the `agd` CLI, and the benches all share:
//!
//! ```text
//! {"prompt": "red circle", "policy": "compressed-cfg", "period": 4}
//! agd generate --policy adaptive-scale --s-max 7.5 --s-min 1.5
//! ```
//!
//! [`coordinator::ext`] shows the extension path: two follow-up-literature
//! policies implemented purely as plugins.
//!
//! ## The scheduling layer
//!
//! Because policies make per-request cost dynamic, the engine schedules
//! work through a pluggable [`Scheduler`] ([`sched`]): `fifo` (default,
//! bit-identical to strict arrival order), `cost-aware`
//! (shortest-remaining-NFE-first on the live per-request estimate),
//! `deadline` (EDF) and `fair-share` (round-robin client lanes). An
//! [`Admission`] budget sheds load past the queued-NFE limit with a
//! structured `queue_full` error, and a [`Telemetry`] registry
//! (`{"cmd": "stats"}` over the wire) tracks occupancy, queue depth and
//! per-policy NFE savings:
//!
//! ```text
//! agd serve --scheduler cost-aware --max-queued-nfes 4000 \
//!     --policy-file presets.json
//! ```
//!
//! ## The zero-allocation hot path (§Perf)
//!
//! Backends execute packed batches — [`Backend::denoise_into`] over a
//! reusable [`BatchBuf`]/[`BatchOut`] pair — and the engine threads a
//! length-keyed [`BufPool`] through the per-step path (in-place input
//! fills, fused combine+gamma, in-place solver), so `pump()` performs no
//! heap allocation at steady state (`rust/tests/zero_alloc.rs` pins this
//! with a counting allocator). See `coordinator::engine`'s
//! "§Perf: buffer ownership & parallel execution" notes before touching
//! the step path.
//!
//! ## The multi-core execution layer (§Perf)
//!
//! The two embarrassingly parallel hot loops — packed batch rows inside
//! [`Backend::denoise_into`] and per-slot step completion — shard across
//! an [`exec::ExecPool`] (`agd serve --workers N`, default = available
//! parallelism). Parallelism is strictly across rows/slots, so results
//! are bit-identical for any worker count; the PJRT client is not `Send`
//! and always stays on the engine thread ([`exec`] module docs).
//!
//! ## The engine fleet (§Scale)
//!
//! The serving stack scales *out* by replicating whole engines: `agd
//! serve --shards N` runs N engine replicas (each on its own thread with
//! its own backend/scheduler/pools — the PJRT one-thread-per-device
//! boundary) behind a load-aware router ([`fleet`]):
//! `--placement least-loaded|round-robin|client-hash`, two-level
//! admission (global budget at the router, per-shard budgets in each
//! engine), optional deadline-infeasibility shedding
//! (`--shed-infeasible`), merged `shard=`-labelled telemetry, and a
//! graceful `{"cmd": "drain"}` quiesce. Placement changes batching, never
//! per-request math — completions are byte-identical for every shard
//! count (`rust/tests/fleet_integration.rs`). The front door is the
//! poll-based connection [`reactor`] (one event-loop thread multiplexing
//! thousands of persistent connections; wire-level request ids,
//! pipelining, per-step progress streaming, and `{"cmd": "cancel"}` —
//! protocol in `docs/PROTOCOL.md`); `--net threads` keeps the
//! thread-per-connection loop as the A/B baseline.
//!
//! ## The chaos harness (§Robustness)
//!
//! Because every claim above rests on byte-identical completions, the
//! serving stack is falsifiable on purpose: [`chaos`] records live
//! traffic (`agd serve --trace-out`), replays it open-loop over real TCP
//! (`agd replay --trace F --speed X --connections N`, reporting wire
//! latency + per-request completion digests into `BENCH_replay.json`),
//! and drives scripted faults — `kill-shard`, disconnects, slowloris,
//! malformed frames, drains — from `scenarios/*.txt` against a live
//! fleet (`rust/tests/chaos_integration.rs`). Faults shed with
//! structured codes; survivors stay byte-identical to a clean run.
//!
//! ## The survival layer (§Robustness)
//!
//! Shedding is the last resort; absorbing comes first. Every serving
//! shard's backend sits behind a fault-injectable wrapper
//! ([`chaos::fault::FaultyBackend`], armed by `agd serve --fault-spec`
//! or the director's `fault` op), and three mechanisms turn injected —
//! or real — failures into completions instead of codes: **bounded
//! batch retry** (`--max-batch-retries`: transient denoise failures
//! roll the batch back and retry under seeded jittered backoff),
//! **work salvage** (a dying shard hands its never-started requests
//! back to the router for re-placement on survivors), and **supervised
//! respawn** (`--shard-respawn`: dead shards are rebuilt from the same
//! backend factory under capped exponential backoff). All three
//! preserve the invariant: retried, salvaged, and post-respawn
//! completions are byte-identical to a fault-free run. The failure
//! taxonomy, error-code catalogue, fault-spec grammar, and scenario
//! authoring guide live in `docs/ROBUSTNESS.md`.
//!
//! ## The observability layer (§Observability)
//!
//! Aggregate counters say *that* AG saves NFEs; the tracing layer
//! ([`trace`]) says *where each request spent its time* and *what the
//! policy decided at every step*. Engines record lifecycle spans
//! (admission → placement → queue → batch → denoise → combine →
//! complete) and one guidance-decision event per denoising step into
//! per-shard preallocated ring buffers — the zero-alloc `pump()`
//! invariant holds with tracing on. Opt a request in with
//! `"trace": true` (its timeline is echoed on the completion line),
//! drain everything with `{"cmd": "spans"}`, and render with
//! `agd profile --spans FILE` — Chrome trace-event JSON for Perfetto,
//! per-stage p50/p95/p99, and the per-policy realized-NFE-savings
//! ledger. The full metric/span catalogue lives in
//! `docs/OBSERVABILITY.md`.
//!
//! Start with [`coordinator::engine::Engine`] and the constructor helpers
//! in [`coordinator::policy`] (`cfg`, `ag`, …); see
//! `examples/quickstart.rs`.

pub mod backend;
pub mod chaos;
pub mod coordinator;
pub mod eval;
pub mod exec;
pub mod fleet;
pub mod metrics;
pub mod ols;
pub mod perfstat;
pub mod prompts;
pub mod quality;
pub mod reactor;
pub mod render;
pub mod runtime;
pub mod sched;
pub mod search;
pub mod server;
pub mod sim;
pub mod stats;
pub mod tensor;
pub mod testing;
pub mod trace;
pub mod util;

pub use backend::{Backend, BatchBuf, BatchOut, EvalInput, GmmBackend};
pub use coordinator::bufpool::BufPool;
pub use exec::ExecPool;
pub use coordinator::engine::{Engine, EngineLoad};
pub use fleet::{Fleet, FleetConfig, Placement};
pub use coordinator::policy::{Policy, PolicyRef, PolicyState, StepObservation, StepPlan};
pub use coordinator::request::{Completion, Request};
pub use coordinator::spec::{PolicyRegistry, PolicySpec, SpecError};
pub use sched::{Admission, AdmitError, Scheduler, SchedulerKind, Telemetry};
