//! Labelled serving telemetry: counters, gauges and fixed-bin histograms.
//!
//! Replaces the engine's ad-hoc `BatchStats` with a registry the whole
//! serving stack shares. Metrics are keyed by name plus a small sorted
//! label set (`policy=`, `client=`, …), so per-policy NFE totals and
//! per-client completion counts fall out of the same three primitives. The
//! server's `{"cmd": "stats"}` line dumps the registry as JSON.
//!
//! Histograms are fixed-bin (`stats::hist::Histogram`) with an exact
//! running sum — memory stays constant under open-ended traffic (unlike
//! the sample-vector `LatencyRecorder`, which is for bounded bench runs),
//! at the price of bin-resolution quantiles. Label *values* are also
//! bounded: each label key (e.g. `client`) keeps at most
//! [`LABEL_VALUE_CAP`] distinct values, and later values collapse into
//! `other` — an open-ended client-id stream cannot grow the registry.
//!
//! Two wire forms share the registry: the JSON dump ([`Telemetry::to_json`],
//! the server's `{"cmd": "stats"}`) and the Prometheus text exposition
//! ([`Telemetry::to_prometheus`], the server's `{"cmd": "metrics"}`) —
//! `# TYPE`-annotated counter/gauge/histogram samples, with histogram bins
//! rendered as cumulative `_bucket{le=...}` series plus `_sum`/`_count`.
//!
//! # §Scale: registry merge
//!
//! Each engine replica in a fleet owns its own registry (the engine is
//! single-threaded); the fleet front-end aggregates them on demand with
//! [`Telemetry::absorb`]: folding a shard's snapshot in once *with* a
//! `("shard", "N")` label yields the per-shard series, folding it in again
//! *without* the label yields the fleet totals — counters and histogram
//! bins add. Gauges only exist under their `shard=` label: intensive
//! gauges (`parallel_efficiency`, `worker_occupancy`) have no meaningful
//! sum, so the unlabelled merge skips gauges entirely and the fleet
//! publishes the extensive totals (`active_requests`, `queue_depth`,
//! `queued_nfes`) itself from its scalar per-shard snapshots.
//!
//! # §Robustness: fleet-level counters
//!
//! A dead shard's registry is unreachable (its engine thread is gone), so
//! robustness events are counted in a registry owned by the fleet
//! front-end itself and folded into the same merge ([`crate::fleet`]):
//!
//! * `shard_died_total{shard=N}` — lifetime shard deaths (pump failure
//!   or injected fault); read from the router's persistent death ledger,
//!   so it survives both the shard's own registry and a supervisor
//!   respawn ([`crate::fleet::ShardLoad`]).
//! * `shard_respawned_total{shard=N}` — supervisor rebuilds of a dead
//!   shard (`--shard-respawn`).
//! * `jobs_salvaged_total{shard=N}` — never-started jobs reclaimed from
//!   dying shard N and re-placed on survivors.
//! * `chaos_kill_shard_total{shard=N}` — fault injections delivered via
//!   `Fleet::kill_shard` (the chaos harness, [`crate::chaos`]).
//! * `conn_bad_line_total{kind=utf8|oversized}` — refused wire frames
//!   (server hardening: non-UTF-8 lines, `--max-line-bytes` cap).
//! * `conn_timeout_total{kind=idle|midline}` — connections cut off at
//!   `--read-timeout-ms` (idle peers vs slowloris mid-line stalls).
//!
//! Engine-side survival counters ride the normal per-shard registries:
//! `batch_retries_total{class=..}` and the `retry_backoff_ms` histogram
//! (bounded batch retry, [`crate::coordinator::engine`]). The full
//! failure taxonomy lives in `docs/ROBUSTNESS.md`.

use std::collections::{BTreeMap, BTreeSet};

use crate::stats::hist::Histogram;
use crate::util::json::{self, Value};

/// Most distinct values one label key may hold; the overflow shares the
/// `other` value. Applies to every metric written through the registry.
pub const LABEL_VALUE_CAP: usize = 64;

/// §Observability: shape of the per-pump `stage_ms{stage=..}` histograms
/// (batch assembly / denoise / combine, 0..1 s in 10 ms bins). Fed by the
/// engine from the same clock the trace spans use, so the aggregate
/// distribution and a drained timeline agree.
pub const STAGE_HIST: (f64, f64, usize) = (0.0, 1_000.0, 100);

/// Registry key: metric name + sorted `(label, value)` pairs.
type Key = (String, Vec<(String, String)>);

/// A pre-computed registry key for hot-path metrics: build once with
/// [`Telemetry::metric_key`], then write through [`Telemetry::inc_key`] /
/// [`Telemetry::set_gauge_key`] / [`Telemetry::observe_key`]. After the
/// series exists, key-based writes touch no heap — the engine's per-pump
/// gauges and occupancy histogram go through these (§Perf).
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct MetricKey(Key);

/// Raw key for *reads*: no cardinality bookkeeping (a capped-out series
/// simply does not exist under its raw value — its data lives in `other`).
fn key(name: &str, labels: &[(&str, &str)]) -> Key {
    let mut ls: Vec<(String, String)> = labels
        .iter()
        .map(|(k, v)| ((*k).to_owned(), (*v).to_owned()))
        .collect();
    ls.sort();
    (name.to_owned(), ls)
}

/// Flat display form: `name` or `name{k=v,k=v}` — the JSON dump's keys.
fn flat(k: &Key) -> String {
    if k.1.is_empty() {
        k.0.clone()
    } else {
        let labels: Vec<String> = k.1.iter().map(|(l, v)| format!("{l}={v}")).collect();
        format!("{}{{{}}}", k.0, labels.join(","))
    }
}

/// Fixed-bin histogram cell with an exact running sum for the mean (the
/// sample count lives in `hist.total`).
#[derive(Debug, Clone)]
struct HistCell {
    hist: Histogram,
    sum: f64,
}

impl HistCell {
    fn observe(&mut self, v: f64) {
        self.hist.add(v);
        self.sum += v;
    }

    fn mean(&self) -> f64 {
        if self.hist.total == 0 {
            0.0
        } else {
            self.sum / self.hist.total as f64
        }
    }

    /// Quantile at bin-center resolution via the cumulative bin counts.
    fn quantile(&self, q: f64) -> f64 {
        if self.hist.total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.hist.total as f64).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (i, &c) in self.hist.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return self.hist.bin_center(i);
            }
        }
        self.hist.bin_center(self.hist.counts.len() - 1)
    }
}

/// The metrics registry (see module docs). Single-threaded like the engine
/// that owns it; front-ends read it through the engine's stats snapshot
/// (fleet shards ship a `Clone` of the registry to the router thread for
/// merging — see [`Telemetry::absorb`]).
#[derive(Debug, Default, Clone)]
pub struct Telemetry {
    counters: BTreeMap<Key, u64>,
    gauges: BTreeMap<Key, f64>,
    hists: BTreeMap<Key, HistCell>,
    /// distinct values seen per label key, for the [`LABEL_VALUE_CAP`]
    /// bound on write paths
    label_values: BTreeMap<String, BTreeSet<String>>,
}

impl Telemetry {
    pub fn new() -> Telemetry {
        Telemetry::default()
    }

    /// Admit one label value against the per-label-key cardinality cap;
    /// past the cap it collapses into `other`.
    fn cap_value(&mut self, label_key: &str, v: &str) -> String {
        let values = self.label_values.entry(label_key.to_owned()).or_default();
        if values.contains(v) {
            v.to_owned()
        } else if values.len() < LABEL_VALUE_CAP {
            values.insert(v.to_owned());
            v.to_owned()
        } else {
            "other".to_owned()
        }
    }

    /// Write-path key: like [`key`], but each label value is admitted
    /// against the per-label-key cardinality cap; past the cap it becomes
    /// `other`.
    fn canonical_key(&mut self, name: &str, labels: &[(&str, &str)]) -> Key {
        let mut ls: Vec<(String, String)> = Vec::with_capacity(labels.len());
        for (k, v) in labels {
            let v = self.cap_value(k, v);
            ls.push(((*k).to_owned(), v));
        }
        ls.sort();
        (name.to_owned(), ls)
    }

    /// Write-path key over an already-owned label set, optionally extended
    /// by one more `(key, value)` pair — the merge path ([`Self::absorb`]).
    fn absorb_key(
        &mut self,
        name: &str,
        labels: &[(String, String)],
        extra: Option<(&str, &str)>,
    ) -> Key {
        let mut ls: Vec<(String, String)> = Vec::with_capacity(labels.len() + 1);
        for (k, v) in labels {
            let v = self.cap_value(k, v);
            ls.push((k.clone(), v));
        }
        if let Some((k, v)) = extra {
            let v = self.cap_value(k, v);
            ls.push((k.to_owned(), v));
        }
        ls.sort();
        (name.to_owned(), ls)
    }

    /// Fold another registry into this one (§Scale: registry merge).
    /// Every series of `part` is re-keyed with `extra` appended to its
    /// label set (`Some(("shard", "2"))` → the per-shard view) or taken
    /// as-is (`None` → fleet totals). Counters and histogram bins add.
    /// Gauges are copied only in *labelled* merges: summing gauges across
    /// replicas is meaningless for intensive ones (`parallel_efficiency`
    /// 0.9 + 0.9 = an impossible 1.8), so an unlabelled merge skips them
    /// and the caller publishes whichever extensive totals it owns (the
    /// fleet sets `active_requests`/`queue_depth`/`queued_nfes` from its
    /// scalar snapshots). Histograms only merge into a series of
    /// identical shape (`lo`/`hi`/bins) — a mismatched shape is dropped
    /// rather than corrupted, which cannot happen between replicas of
    /// the same engine.
    pub fn absorb(&mut self, part: &Telemetry, extra: Option<(&str, &str)>) {
        for ((name, labels), &v) in &part.counters {
            let k = self.absorb_key(name, labels, extra);
            *self.counters.entry(k).or_insert(0) += v;
        }
        if extra.is_some() {
            for ((name, labels), &v) in &part.gauges {
                let k = self.absorb_key(name, labels, extra);
                self.gauges.insert(k, v);
            }
        }
        for ((name, labels), cell) in &part.hists {
            let k = self.absorb_key(name, labels, extra);
            match self.hists.get_mut(&k) {
                Some(mine)
                    if mine.hist.lo == cell.hist.lo
                        && mine.hist.hi == cell.hist.hi
                        && mine.hist.counts.len() == cell.hist.counts.len() =>
                {
                    for (a, b) in mine.hist.counts.iter_mut().zip(&cell.hist.counts) {
                        *a += b;
                    }
                    mine.hist.total += cell.hist.total;
                    mine.sum += cell.sum;
                }
                Some(_) => {} // shape mismatch: refuse to corrupt the bins
                None => {
                    self.hists.insert(k, cell.clone());
                }
            }
        }
    }

    /// Increment a counter.
    pub fn inc(&mut self, name: &str, labels: &[(&str, &str)], by: u64) {
        let k = self.canonical_key(name, labels);
        *self.counters.entry(k).or_insert(0) += by;
    }

    /// Set a gauge to its current value.
    pub fn set_gauge(&mut self, name: &str, labels: &[(&str, &str)], v: f64) {
        let k = self.canonical_key(name, labels);
        self.gauges.insert(k, v);
    }

    /// Record one histogram sample. `lo`/`hi`/`bins` size the histogram on
    /// first use of the (name, labels) series; out-of-range samples clamp
    /// into the edge bins (the count/sum stay exact).
    pub fn observe(
        &mut self,
        name: &str,
        labels: &[(&str, &str)],
        v: f64,
        lo: f64,
        hi: f64,
        bins: usize,
    ) {
        let k = self.canonical_key(name, labels);
        self.hists
            .entry(k)
            .or_insert_with(|| HistCell {
                hist: Histogram::new(lo, hi, bins),
                sum: 0.0,
            })
            .observe(v);
    }

    /// Build a reusable write key. Label values pass through the same
    /// cardinality cap as the string write path.
    pub fn metric_key(&mut self, name: &str, labels: &[(&str, &str)]) -> MetricKey {
        MetricKey(self.canonical_key(name, labels))
    }

    /// [`Self::inc`] through a pre-computed key (allocation-free once the
    /// series exists).
    pub fn inc_key(&mut self, k: &MetricKey, by: u64) {
        match self.counters.get_mut(&k.0) {
            Some(v) => *v += by,
            None => {
                self.counters.insert(k.0.clone(), by);
            }
        }
    }

    /// [`Self::set_gauge`] through a pre-computed key.
    pub fn set_gauge_key(&mut self, k: &MetricKey, v: f64) {
        match self.gauges.get_mut(&k.0) {
            Some(slot) => *slot = v,
            None => {
                self.gauges.insert(k.0.clone(), v);
            }
        }
    }

    /// [`Self::observe`] through a pre-computed key; `lo`/`hi`/`bins` size
    /// the histogram on first use only.
    pub fn observe_key(&mut self, k: &MetricKey, v: f64, lo: f64, hi: f64, bins: usize) {
        match self.hists.get_mut(&k.0) {
            Some(cell) => cell.observe(v),
            None => {
                let mut cell = HistCell {
                    hist: Histogram::new(lo, hi, bins),
                    sum: 0.0,
                };
                cell.observe(v);
                self.hists.insert(k.0.clone(), cell);
            }
        }
    }

    /// Current counter value (0 if never incremented).
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> u64 {
        self.counters.get(&key(name, labels)).copied().unwrap_or(0)
    }

    /// Current gauge value.
    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        self.gauges.get(&key(name, labels)).copied()
    }

    /// Sample count of a histogram series (0 if absent).
    pub fn hist_count(&self, name: &str, labels: &[(&str, &str)]) -> u64 {
        self.hists.get(&key(name, labels)).map_or(0, |h| h.hist.total)
    }

    /// Mean of a histogram series (exact, from the running sum).
    pub fn hist_mean(&self, name: &str, labels: &[(&str, &str)]) -> f64 {
        self.hists.get(&key(name, labels)).map_or(0.0, HistCell::mean)
    }

    /// Sum all counters sharing `name` (across label sets) — e.g. total
    /// NFEs over every `policy=` label.
    pub fn counter_sum(&self, name: &str) -> u64 {
        self.counters
            .iter()
            .filter(|((n, _), _)| n == name)
            .map(|(_, v)| *v)
            .sum()
    }

    /// Dump the registry:
    /// `{"counters": {...}, "gauges": {...}, "histograms": {"name{l=v}":
    /// {"count": n, "mean": m, "p50": ..., "p99": ...}}}`.
    pub fn to_json(&self) -> Value {
        let counters = Value::Obj(
            self.counters
                .iter()
                .map(|(k, &v)| (flat(k), json::num(v as f64)))
                .collect(),
        );
        let gauges = Value::Obj(
            self.gauges
                .iter()
                .map(|(k, &v)| (flat(k), json::num(v)))
                .collect(),
        );
        let hists = Value::Obj(
            self.hists
                .iter()
                .map(|(k, h)| {
                    let cell = json::obj(vec![
                        ("count", json::num(h.hist.total as f64)),
                        ("mean", json::num(h.mean())),
                        ("p50", json::num(h.quantile(0.50))),
                        ("p99", json::num(h.quantile(0.99))),
                    ]);
                    (flat(k), cell)
                })
                .collect(),
        );
        json::obj(vec![
            ("counters", counters),
            ("gauges", gauges),
            ("histograms", hists),
        ])
    }

    /// Render the registry as Prometheus text exposition (format 0.0.4):
    /// one `# TYPE` line per metric name, then one sample per label set.
    /// Counter and gauge names pass through unchanged; each histogram
    /// series becomes cumulative `name_bucket{...,le="<edge>"}` samples
    /// over its fixed bins (the top edge is `+Inf` — out-of-range samples
    /// clamp into the edge bins, so interior bucket boundaries are
    /// approximate at the extremes while `_sum`/`_count` stay exact).
    /// Keys sort by (name, labels), so `# TYPE` grouping falls out of the
    /// `BTreeMap` order.
    pub fn to_prometheus(&self) -> String {
        fn labels_block(labels: &[(String, String)], extra: Option<(&str, &str)>) -> String {
            let mut parts: Vec<String> = labels
                .iter()
                .map(|(k, v)| format!("{k}=\"{}\"", prom_escape(v)))
                .collect();
            if let Some((k, v)) = extra {
                parts.push(format!("{k}=\"{v}\""));
            }
            if parts.is_empty() {
                String::new()
            } else {
                format!("{{{}}}", parts.join(","))
            }
        }

        let mut out = String::new();
        let mut last_type: Option<(String, &str)> = None;
        let mut type_line = |out: &mut String, name: &str, kind: &'static str| {
            if last_type.as_ref().map(|(n, k)| (n.as_str(), *k)) != Some((name, kind)) {
                out.push_str(&format!("# TYPE {name} {kind}\n"));
                last_type = Some((name.to_owned(), kind));
            }
        };

        for ((name, labels), v) in &self.counters {
            type_line(&mut out, name, "counter");
            out.push_str(&format!("{name}{} {v}\n", labels_block(labels, None)));
        }
        for ((name, labels), v) in &self.gauges {
            type_line(&mut out, name, "gauge");
            out.push_str(&format!("{name}{} {v}\n", labels_block(labels, None)));
        }
        for ((name, labels), cell) in &self.hists {
            type_line(&mut out, name, "histogram");
            let h = &cell.hist;
            let width = (h.hi - h.lo) / h.counts.len() as f64;
            let mut cum = 0u64;
            for (i, &c) in h.counts.iter().enumerate() {
                cum += c;
                let le = if i + 1 == h.counts.len() {
                    "+Inf".to_owned()
                } else {
                    format!("{}", h.lo + (i as f64 + 1.0) * width)
                };
                out.push_str(&format!(
                    "{name}_bucket{} {cum}\n",
                    labels_block(labels, Some(("le", le.as_str())))
                ));
            }
            out.push_str(&format!(
                "{name}_sum{} {}\n",
                labels_block(labels, None),
                cell.sum
            ));
            out.push_str(&format!(
                "{name}_count{} {}\n",
                labels_block(labels, None),
                h.total
            ));
        }
        out
    }
}

/// Escape a Prometheus label value: backslash, double quote, newline.
fn prom_escape(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for ch in v.chars() {
        match ch {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            other => out.push(other),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_per_label_set() {
        let mut t = Telemetry::new();
        t.inc("nfes_total", &[("policy", "ag")], 30);
        t.inc("nfes_total", &[("policy", "ag")], 10);
        t.inc("nfes_total", &[("policy", "cfg")], 40);
        assert_eq!(t.counter("nfes_total", &[("policy", "ag")]), 40);
        assert_eq!(t.counter("nfes_total", &[("policy", "cfg")]), 40);
        assert_eq!(t.counter("nfes_total", &[("policy", "cond")]), 0);
        assert_eq!(t.counter_sum("nfes_total"), 80);
    }

    #[test]
    fn label_order_does_not_matter() {
        let mut t = Telemetry::new();
        t.inc("done", &[("policy", "ag"), ("client", "web")], 1);
        t.inc("done", &[("client", "web"), ("policy", "ag")], 1);
        assert_eq!(t.counter("done", &[("policy", "ag"), ("client", "web")]), 2);
    }

    #[test]
    fn gauges_overwrite() {
        let mut t = Telemetry::new();
        t.set_gauge("queue_depth", &[], 5.0);
        t.set_gauge("queue_depth", &[], 2.0);
        assert_eq!(t.gauge("queue_depth", &[]), Some(2.0));
        assert_eq!(t.gauge("missing", &[]), None);
    }

    #[test]
    fn histogram_mean_and_quantiles() {
        let mut t = Telemetry::new();
        for i in 1..=100 {
            t.observe("wait_ms", &[], i as f64, 0.0, 100.0, 100);
        }
        assert_eq!(t.hist_count("wait_ms", &[]), 100);
        assert!((t.hist_mean("wait_ms", &[]) - 50.5).abs() < 1e-9);
        // bin-center resolution: p50 lands in the middle, p99 near the top
        let json = t.to_json();
        let h = json.req("histograms").req("wait_ms");
        let p50 = h.req("p50").as_f64().unwrap();
        let p99 = h.req("p99").as_f64().unwrap();
        assert!((p50 - 50.0).abs() <= 1.0, "{p50}");
        assert!(p99 >= 98.0, "{p99}");
    }

    #[test]
    fn json_dump_flattens_labels() {
        let mut t = Telemetry::new();
        t.inc("nfes_total", &[("policy", "ag")], 12);
        t.set_gauge("active", &[], 3.0);
        t.observe("exec_ms", &[("policy", "ag")], 4.0, 0.0, 10.0, 10);
        let v = t.to_json();
        assert_eq!(
            v.req("counters").req("nfes_total{policy=ag}").as_f64(),
            Some(12.0)
        );
        assert_eq!(v.req("gauges").req("active").as_f64(), Some(3.0));
        assert_eq!(
            v.req("histograms").req("exec_ms{policy=ag}").req("count").as_f64(),
            Some(1.0)
        );
        // the dump is valid JSON end-to-end
        let text = json::to_string(&v);
        assert!(json::parse(&text).is_ok(), "{text}");
    }

    #[test]
    fn label_values_are_capped_per_key() {
        let mut t = Telemetry::new();
        for i in 0..(LABEL_VALUE_CAP + 5) {
            let v = format!("c{i}");
            t.inc("done", &[("client", v.as_str())], 1);
        }
        // the first CAP values keep their own series, the rest pool up
        assert_eq!(t.counter("done", &[("client", "c0")]), 1);
        assert_eq!(t.counter("done", &[("client", "other")]), 5);
        assert_eq!(t.counter_sum("done"), (LABEL_VALUE_CAP + 5) as u64);
        // a different label key has its own budget
        t.inc("done", &[("policy", "ag")], 1);
        assert_eq!(t.counter("done", &[("policy", "ag")]), 1);
    }

    #[test]
    fn precomputed_keys_share_series_with_string_writes() {
        let mut t = Telemetry::new();
        let k = t.metric_key("nfes_total", &[("policy", "ag")]);
        t.inc_key(&k, 2);
        t.inc("nfes_total", &[("policy", "ag")], 3);
        assert_eq!(t.counter("nfes_total", &[("policy", "ag")]), 5);

        let g = t.metric_key("active", &[]);
        t.set_gauge_key(&g, 4.0);
        t.set_gauge_key(&g, 2.5);
        assert_eq!(t.gauge("active", &[]), Some(2.5));

        let h = t.metric_key("occ", &[]);
        t.observe_key(&h, 1.0, 0.0, 10.0, 10);
        t.observe_key(&h, 3.0, 0.0, 10.0, 10);
        assert_eq!(t.hist_count("occ", &[]), 2);
        assert!((t.hist_mean("occ", &[]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn absorb_merges_per_shard_and_total_views() {
        let mk = |nfes: u64, active: f64, wait: f64| {
            let mut t = Telemetry::new();
            t.inc("nfes_total", &[("policy", "ag")], nfes);
            t.set_gauge("active_requests", &[], active);
            t.observe("queue_wait_ms", &[("policy", "ag")], wait, 0.0, 100.0, 10);
            t
        };
        let shards = [mk(30, 2.0, 5.0), mk(12, 1.0, 95.0)];
        let mut merged = Telemetry::new();
        for (i, part) in shards.iter().enumerate() {
            merged.absorb(part, None); // fleet totals
            let shard = format!("{i}");
            merged.absorb(part, Some(("shard", &shard)));
        }
        // totals: counters sum across shards; gauges deliberately do NOT
        // appear unlabelled (summing intensive gauges is meaningless —
        // the fleet publishes extensive totals itself)
        assert_eq!(merged.counter("nfes_total", &[("policy", "ag")]), 42);
        assert_eq!(merged.gauge("active_requests", &[]), None);
        assert_eq!(merged.hist_count("queue_wait_ms", &[("policy", "ag")]), 2);
        assert!((merged.hist_mean("queue_wait_ms", &[("policy", "ag")]) - 50.0).abs() < 1e-9);
        // per-shard views survive under the shard label
        assert_eq!(
            merged.counter("nfes_total", &[("policy", "ag"), ("shard", "0")]),
            30
        );
        assert_eq!(
            merged.counter("nfes_total", &[("policy", "ag"), ("shard", "1")]),
            12
        );
        assert_eq!(merged.gauge("active_requests", &[("shard", "1")]), Some(1.0));
        assert_eq!(
            merged.hist_count("queue_wait_ms", &[("policy", "ag"), ("shard", "0")]),
            1
        );
        // absorbing is additive: a second merge round doubles the counters
        merged.absorb(&shards[0], None);
        assert_eq!(merged.counter("nfes_total", &[("policy", "ag")]), 72);
        // and both wire forms render the merged registry
        let text = json::to_string(&merged.to_json());
        assert!(json::parse(&text).is_ok(), "{text}");
        let prom = merged.to_prometheus();
        assert!(
            prom.contains("nfes_total{policy=\"ag\",shard=\"0\"} 30\n"),
            "{prom}"
        );
        assert!(prom.contains("nfes_total{policy=\"ag\"} 72\n"), "{prom}");
    }

    #[test]
    fn absorb_respects_the_label_cap() {
        let mut part = Telemetry::new();
        part.inc("done", &[], 1);
        let mut merged = Telemetry::new();
        for i in 0..(LABEL_VALUE_CAP + 3) {
            let shard = format!("s{i}");
            merged.absorb(&part, Some(("shard", &shard)));
        }
        assert_eq!(merged.counter("done", &[("shard", "s0")]), 1);
        assert_eq!(merged.counter("done", &[("shard", "other")]), 3);
        assert_eq!(merged.counter_sum("done"), (LABEL_VALUE_CAP + 3) as u64);
    }

    #[test]
    fn empty_registry_dumps_cleanly() {
        let t = Telemetry::new();
        let text = json::to_string(&t.to_json());
        assert!(json::parse(&text).is_ok());
        assert_eq!(t.hist_mean("none", &[]), 0.0);
        assert_eq!(t.to_prometheus(), "");
    }

    #[test]
    fn prometheus_exposition_types_and_samples() {
        let mut t = Telemetry::new();
        t.inc("nfes_total", &[("policy", "ag")], 31);
        t.inc("nfes_total", &[("policy", "cfg")], 40);
        t.inc("requests_completed_total", &[("policy", "ag"), ("client", "web")], 2);
        t.set_gauge("active_requests", &[], 3.0);
        for v in [1.0, 15.0, 25.0] {
            t.observe("exec_ms", &[("policy", "ag")], v, 0.0, 30.0, 3);
        }
        let text = t.to_prometheus();
        // every metric name gets exactly one TYPE line
        assert_eq!(text.matches("# TYPE nfes_total counter").count(), 1);
        assert_eq!(text.matches("# TYPE active_requests gauge").count(), 1);
        assert_eq!(text.matches("# TYPE exec_ms histogram").count(), 1);
        // samples carry quoted labels (sorted: client before policy)
        assert!(text.contains("nfes_total{policy=\"ag\"} 31\n"), "{text}");
        assert!(text.contains("nfes_total{policy=\"cfg\"} 40\n"), "{text}");
        assert!(
            text.contains("requests_completed_total{client=\"web\",policy=\"ag\"} 2\n"),
            "{text}"
        );
        assert!(text.contains("active_requests 3\n"), "{text}");
        // histogram: cumulative buckets, +Inf top edge, exact sum/count
        assert!(text.contains("exec_ms_bucket{policy=\"ag\",le=\"10\"} 1\n"), "{text}");
        assert!(text.contains("exec_ms_bucket{policy=\"ag\",le=\"20\"} 2\n"), "{text}");
        assert!(text.contains("exec_ms_bucket{policy=\"ag\",le=\"+Inf\"} 3\n"), "{text}");
        assert!(text.contains("exec_ms_sum{policy=\"ag\"} 41\n"), "{text}");
        assert!(text.contains("exec_ms_count{policy=\"ag\"} 3\n"), "{text}");
        // TYPE line precedes the samples of its metric
        let type_pos = text.find("# TYPE nfes_total counter").unwrap();
        let sample_pos = text.find("nfes_total{policy=\"ag\"}").unwrap();
        assert!(type_pos < sample_pos);
    }

    #[test]
    fn prometheus_label_values_are_escaped() {
        let mut t = Telemetry::new();
        t.inc("done", &[("client", "we\"b\\x\nline")], 1);
        let text = t.to_prometheus();
        assert!(
            text.contains("done{client=\"we\\\"b\\\\x\\nline\"} 1\n"),
            "{text}"
        );
    }

    /// §Observability edge cases in the exposition: a histogram whose
    /// every sample clamps into an edge bin still renders exact
    /// `_sum`/`_count`, a single-bin histogram renders only the `+Inf`
    /// bucket, and reading a series that was never observed is defined
    /// (zero), not a panic.
    #[test]
    fn prometheus_histogram_edge_cases() {
        let mut t = Telemetry::new();
        // out-of-range on both sides: clamped bins, exact sum/count
        t.observe("clamp_ms", &[], -5.0, 0.0, 10.0, 2);
        t.observe("clamp_ms", &[], 99.0, 0.0, 10.0, 2);
        // single bin: the only bucket edge is +Inf
        t.observe("one_bin", &[], 3.0, 0.0, 10.0, 1);
        let text = t.to_prometheus();
        assert!(text.contains("clamp_ms_bucket{le=\"5\"} 1\n"), "{text}");
        assert!(text.contains("clamp_ms_bucket{le=\"+Inf\"} 2\n"), "{text}");
        assert!(text.contains("clamp_ms_sum 94\n"), "{text}");
        assert!(text.contains("clamp_ms_count 2\n"), "{text}");
        assert_eq!(text.matches("one_bin_bucket").count(), 1, "{text}");
        assert!(text.contains("one_bin_bucket{le=\"+Inf\"} 1\n"), "{text}");

        // an "empty" histogram (series exists, zero samples) only arises
        // through the merge path: absorb a shard, then render — the shard
        // itself may have series this registry never observed into
        let mut merged = Telemetry::new();
        merged.absorb(&t, Some(("shard", "0")));
        let text = merged.to_prometheus();
        assert!(
            text.contains("one_bin_count{shard=\"0\"} 1\n"),
            "{text}"
        );
        // quantiles of a zero-sample cell are defined (0.0), not a panic
        assert_eq!(merged.hist_mean("never_observed", &[]), 0.0);
        assert_eq!(merged.hist_count("never_observed", &[]), 0);
    }

    /// Past [`LABEL_VALUE_CAP`] the overflow series renders as
    /// `other` in the exposition — the text stays bounded and parseable.
    #[test]
    fn prometheus_renders_capped_overflow_as_other() {
        let mut t = Telemetry::new();
        for i in 0..(LABEL_VALUE_CAP + 7) {
            let c = format!("client-{i}");
            t.inc("done", &[("client", c.as_str())], 1);
        }
        let text = t.to_prometheus();
        assert!(text.contains("done{client=\"other\"} 7\n"), "{text}");
        assert!(text.contains("done{client=\"client-0\"} 1\n"), "{text}");
        // one series per admitted value + the shared overflow series
        assert_eq!(
            text.matches("\ndone{").count() + usize::from(text.starts_with("done{")),
            LABEL_VALUE_CAP + 1,
            "{text}"
        );
    }

    /// §Observability: the engine's per-pump `stage_ms{stage=..}`
    /// histograms ([`STAGE_HIST`]) merge across shards like any other
    /// series — bins add under the fleet total and survive per-shard —
    /// while a shape-mismatched series is dropped, not corrupted.
    #[test]
    fn absorb_merges_stage_histograms() {
        let (lo, hi, bins) = STAGE_HIST;
        let mk = |batch: f64, denoise: f64| {
            let mut t = Telemetry::new();
            t.observe("stage_ms", &[("stage", "batch")], batch, lo, hi, bins);
            t.observe("stage_ms", &[("stage", "denoise")], denoise, lo, hi, bins);
            t
        };
        let shards = [mk(1.0, 40.0), mk(3.0, 60.0)];
        let mut merged = Telemetry::new();
        for (i, part) in shards.iter().enumerate() {
            merged.absorb(part, None);
            let shard = format!("{i}");
            merged.absorb(part, Some(("shard", &shard)));
        }
        assert_eq!(merged.hist_count("stage_ms", &[("stage", "batch")]), 2);
        assert_eq!(merged.hist_count("stage_ms", &[("stage", "denoise")]), 2);
        assert!(
            (merged.hist_mean("stage_ms", &[("stage", "denoise")]) - 50.0).abs() < 1e-9
        );
        assert_eq!(
            merged.hist_count("stage_ms", &[("stage", "denoise"), ("shard", "1")]),
            1
        );
        let prom = merged.to_prometheus();
        assert!(prom.contains("# TYPE stage_ms histogram"), "{prom}");
        assert!(prom.contains("stage_ms_count{stage=\"denoise\"} 2\n"), "{prom}");

        // a same-name series with a different bin shape refuses to merge
        // into the existing bins (dropped, totals unchanged)
        let mut odd = Telemetry::new();
        odd.observe("stage_ms", &[("stage", "batch")], 1.0, 0.0, 10.0, 5);
        merged.absorb(&odd, None);
        assert_eq!(merged.hist_count("stage_ms", &[("stage", "batch")]), 2);
    }
}
