//! Cost-aware scheduling, admission control and serving telemetry.
//!
//! The paper's policies make per-request cost *dynamic*: AG truncates the
//! unconditional stream mid-request, LINEARAG replaces whole evaluations
//! with an affine extrapolation, Compress-Guidance-style plugins widen the
//! spread further. Two requests with the same step count can therefore
//! differ 2× in remaining work — and a FIFO batcher lets cheap truncated
//! requests queue behind expensive full-CFG ones, exploding tail latency
//! under open-loop traffic. This module gives the engine the three serving
//! controls that exploit the cost signal instead of ignoring it:
//!
//!  * [`Scheduler`] ([`scheduler`]) — the ordering discipline over pending
//!    work items, with four built-ins: [`Fifo`] (default; bit-for-bit the
//!    historical behaviour), [`CostAware`] (shortest-remaining-NFE-first,
//!    fed by the live per-request cost estimate), [`Deadline`] (EDF over
//!    the optional request deadline/priority) and [`FairShare`]
//!    (round-robin across client lanes).
//!  * [`Admission`] ([`admission`]) — in-flight and queued-NFE budgets
//!    that shed load with a structured `queue_full` error instead of
//!    buffering unboundedly.
//!  * [`Telemetry`] ([`telemetry`]) — a labelled counter/gauge/histogram
//!    registry (`policy=`, `client=`) tracking occupancy, queue depth,
//!    per-policy NFEs saved, and per-request queue-wait vs execute time;
//!    dumped over the wire by the server's `{"cmd": "stats"}` line.
//!
//! `agd serve --scheduler cost-aware --max-queued-nfes 4000` selects the
//! discipline and budget; `rust/benches/sched_tail_latency.rs` compares
//! the disciplines under mixed cfg/ag/linear-ag traffic.
//!
//! # Adding a scheduler
//!
//! Mirrors the adding-a-policy guide in [`crate::coordinator::policy`]:
//!
//! 1. Define a struct holding the discipline's queue structure. Per-request
//!    facts arrive as [`RequestMeta`] snapshots at push time — do not cache
//!    engine state beyond what `push` hands you.
//! 2. `impl Scheduler`: `push` enqueues one [`WorkItem`] (a step's slots
//!    arrive back-to-back, in slot order — keep them adjacent so a step
//!    completes in as few batches as possible); `peek_model` names the
//!    model of the batch you would run next; `take_batch(model, cap, out)`
//!    removes up to `cap` items of that model, appending them to the
//!    caller's buffer in your order — keep any selection scratch on the
//!    struct so steady-state pops allocate nothing (`tests/zero_alloc.rs`
//!    pins this for the built-ins); `forget` drops per-request
//!    bookkeeping; `revoke` additionally removes the request's *queued*
//!    items (§Robustness: the fleet's shard-death salvage pulls
//!    never-started requests back — a queue-holding discipline that only
//!    takes the default `revoke` would orphan their items). Be
//!    deterministic: break ties by `RequestMeta::id`, never by map
//!    iteration order.
//! 3. Wire a name into [`SchedulerKind`] (parse/build/ALL) and it becomes
//!    reachable from `agd serve --scheduler`, the bench harness, and
//!    [`crate::Engine::with_scheduler`] callers.
//! 4. Pin behaviour in tests: scheduler-level ordering unit tests here,
//!    plus an engine-level test in `rust/tests/sched_integration.rs`
//!    proving end-results stay bit-identical to [`Fifo`] (scheduling must
//!    reorder *work*, never change *results*).

pub mod admission;
pub mod scheduler;
pub mod telemetry;

pub use admission::{Admission, AdmitError};
pub use scheduler::{
    CostAware, Deadline, FairShare, Fifo, RequestMeta, Scheduler, SchedulerKind, WorkItem,
};
pub use telemetry::{MetricKey, Telemetry, STAGE_HIST};
