//! Admission control: bounded queues instead of unbounded buffering.
//!
//! The engine's work queue used to grow without limit — under the heavy
//! open-loop traffic of the ROADMAP's north star that means unbounded
//! memory *and* unbounded queue-wait. [`Admission`] caps the queue on two
//! axes: requests in flight and total queued NFEs (the honest unit of
//! pending work, since policies make per-request cost dynamic — a CFG
//! request queues 2·T evals, a truncated AG request far fewer). A request
//! that would exceed either budget is rejected with a typed
//! [`AdmitError`], which the server surfaces as a structured `queue_full`
//! JSON error; in-flight requests are never affected.
//!
//! The global budgets are complemented by a *per-client* in-flight quota
//! (`--max-in-flight-per-client`): without it one client can consume the
//! entire global budget and starve everyone at the admission door (the
//! fair-share scheduler only helps requests that were admitted). The
//! engine tracks live requests per `client_id` (anonymous requests share
//! the `""` lane, mirroring fair-share) and sheds past-quota requests
//! with [`AdmitError::ClientBusy`], which names the per-client limit.
//!
//! # §Scale: two-level admission
//!
//! Under an engine fleet ([`crate::fleet`]) the same [`Admission`] type is
//! checked at **two levels**: the router holds a *fleet-global* budget
//! (`--max-in-flight` / `--max-queued-nfes`, checked against the summed
//! load of every shard before a request is placed) and each shard's engine
//! holds its own *per-shard* budget (`--shard-max-in-flight` /
//! `--shard-max-queued-nfes`). A shed error line carries a
//! `"scope": "global" | "shard"` field
//! ([`ScopedShed`](crate::fleet::ScopedShed)) naming the level that
//! tripped. The per-client quota stays shard-side, where the live
//! per-client counts are; under `client-hash` placement one client always
//! lands on one shard, which makes it an exact fleet-wide quota.
//!
//! A fleet shard can additionally shed *deadline-infeasible* requests at
//! admission (`agd serve --shed-infeasible`): when the shard's observed
//! per-NFE service rate says the queued backlog plus the candidate cannot
//! finish inside the request's `deadline_ms`, the request is refused with
//! [`AdmitError::DeadlineInfeasible`] (wire code `deadline_infeasible`)
//! instead of burning NFEs on a reply that would arrive too late.

use std::fmt;
use std::sync::Arc;

/// Queue budgets. `None` on an axis means unlimited (the default — engine
/// embedders like the drain-mode benches pre-load thousands of requests on
/// purpose).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Admission {
    /// Maximum requests in flight (queued or executing).
    pub max_in_flight: Option<usize>,
    /// Maximum total queued NFEs, counting the candidate's worst case.
    pub max_queued_nfes: Option<usize>,
    /// Maximum requests in flight per `client_id` (anonymous requests
    /// count against the shared `""` client).
    pub max_in_flight_per_client: Option<usize>,
}

impl Admission {
    /// No budgets: everything is admitted.
    pub fn unlimited() -> Admission {
        Admission::default()
    }

    /// Budget check for one candidate request costing up to `request_nfes`
    /// evaluations, against the engine's current load.
    pub fn check(
        &self,
        in_flight: usize,
        queued_nfes: usize,
        request_nfes: usize,
    ) -> Result<(), AdmitError> {
        if let Some(max) = self.max_in_flight {
            if in_flight >= max {
                return Err(AdmitError::InFlightFull { in_flight, max });
            }
        }
        if let Some(max) = self.max_queued_nfes {
            if queued_nfes + request_nfes > max {
                return Err(AdmitError::NfeBudgetFull {
                    queued_nfes,
                    request_nfes,
                    max,
                });
            }
        }
        Ok(())
    }

    /// Per-client quota check: `client_in_flight` is the engine's live
    /// request count for `client`. Checked alongside (after) the global
    /// budgets, so the error a client sees names the binding constraint.
    pub fn check_client(
        &self,
        client: &Arc<str>,
        client_in_flight: usize,
    ) -> Result<(), AdmitError> {
        if let Some(max) = self.max_in_flight_per_client {
            if client_in_flight >= max {
                return Err(AdmitError::ClientBusy {
                    client: client.clone(),
                    in_flight: client_in_flight,
                    max,
                });
            }
        }
        Ok(())
    }
}

/// Why a request was refused at admission. The server maps the shed
/// variants to a `queue_full` error line carrying these numbers (so
/// clients can back off proportionally) and [`AdmitError::Invalid`] to an
/// `invalid_request` line — a malformed request must be rejected at the
/// door, never panic or poison a batch mid-flight.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdmitError {
    InFlightFull {
        in_flight: usize,
        max: usize,
    },
    NfeBudgetFull {
        queued_nfes: usize,
        request_nfes: usize,
        max: usize,
    },
    /// The client is over its per-client in-flight quota
    /// (`--max-in-flight-per-client`); other clients are unaffected.
    ClientBusy {
        client: Arc<str>,
        in_flight: usize,
        max: usize,
    },
    /// The request's deadline cannot be met given the shard's queued
    /// backlog and observed per-NFE service rate (`--shed-infeasible`);
    /// wire code `deadline_infeasible`. `queued_nfes` includes the
    /// candidate's own cost.
    DeadlineInfeasible {
        deadline_ms: u64,
        estimated_ms: u64,
        queued_nfes: usize,
    },
    /// The request itself is malformed (`Engine::try_submit`'s up-front
    /// shape checks: empty tokens, mismatched negative-prompt width, zero
    /// steps).
    Invalid { reason: &'static str },
}

impl fmt::Display for AdmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AdmitError::InFlightFull { in_flight, max } => write!(
                f,
                "queue full: {in_flight} requests in flight (limit {max})"
            ),
            AdmitError::NfeBudgetFull {
                queued_nfes,
                request_nfes,
                max,
            } => write!(
                f,
                "queue full: {queued_nfes} NFEs queued + {request_nfes} requested \
                 exceeds the {max} budget"
            ),
            AdmitError::ClientBusy {
                client,
                in_flight,
                max,
            } => {
                let who: &str = client;
                let who = if who.is_empty() { "<anonymous>" } else { who };
                write!(
                    f,
                    "queue full: client `{who}` has {in_flight} requests in flight \
                     (per-client limit {max})"
                )
            }
            AdmitError::DeadlineInfeasible {
                deadline_ms,
                estimated_ms,
                queued_nfes,
            } => write!(
                f,
                "deadline infeasible: ~{estimated_ms} ms to drain {queued_nfes} queued \
                 NFEs exceeds the {deadline_ms} ms deadline"
            ),
            AdmitError::Invalid { reason } => write!(f, "invalid request: {reason}"),
        }
    }
}

impl std::error::Error for AdmitError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unlimited_admits_everything() {
        let a = Admission::unlimited();
        assert!(a.check(1_000_000, usize::MAX / 2, 1000).is_ok());
    }

    #[test]
    fn in_flight_budget() {
        let a = Admission {
            max_in_flight: Some(2),
            ..Admission::unlimited()
        };
        assert!(a.check(1, 0, 40).is_ok());
        assert_eq!(
            a.check(2, 0, 40),
            Err(AdmitError::InFlightFull { in_flight: 2, max: 2 })
        );
    }

    #[test]
    fn per_client_quota_caps_one_client_only() {
        let a = Admission {
            max_in_flight_per_client: Some(2),
            ..Admission::unlimited()
        };
        let web: Arc<str> = Arc::from("web");
        assert!(a.check_client(&web, 0).is_ok());
        assert!(a.check_client(&web, 1).is_ok());
        let err = a.check_client(&web, 2).unwrap_err();
        assert_eq!(
            err,
            AdmitError::ClientBusy {
                client: web.clone(),
                in_flight: 2,
                max: 2
            }
        );
        let text = err.to_string();
        assert!(text.contains("per-client limit 2"), "{text}");
        assert!(text.contains("web"), "{text}");
        // the anonymous lane renders readably
        let anon: Arc<str> = Arc::from("");
        let text = a.check_client(&anon, 5).unwrap_err().to_string();
        assert!(text.contains("<anonymous>"), "{text}");
        // no quota configured → everything passes
        assert!(Admission::unlimited().check_client(&web, 10_000).is_ok());
    }

    #[test]
    fn nfe_budget_counts_the_candidate() {
        let a = Admission {
            max_queued_nfes: Some(100),
            ..Admission::unlimited()
        };
        assert!(a.check(5, 60, 40).is_ok()); // exactly at budget
        assert_eq!(
            a.check(5, 61, 40),
            Err(AdmitError::NfeBudgetFull {
                queued_nfes: 61,
                request_nfes: 40,
                max: 100
            })
        );
        // a single oversized request is shed even on an empty queue
        assert!(a.check(0, 0, 101).is_err());
    }

    #[test]
    fn errors_render_the_numbers() {
        let e = AdmitError::NfeBudgetFull {
            queued_nfes: 90,
            request_nfes: 40,
            max: 100,
        };
        let text = e.to_string();
        assert!(text.contains("90") && text.contains("40") && text.contains("100"), "{text}");
        assert!(text.contains("queue full"));
    }

    #[test]
    fn infeasible_deadlines_render_the_estimate() {
        let e = AdmitError::DeadlineInfeasible {
            deadline_ms: 50,
            estimated_ms: 420,
            queued_nfes: 84,
        };
        let text = e.to_string();
        assert!(text.starts_with("deadline infeasible"), "{text}");
        assert!(
            text.contains("420") && text.contains("84") && text.contains("50"),
            "{text}"
        );
    }

    #[test]
    fn invalid_requests_render_the_reason() {
        let e = AdmitError::Invalid {
            reason: "tokens must be non-empty",
        };
        let text = e.to_string();
        assert!(text.starts_with("invalid request:"), "{text}");
        assert!(text.contains("tokens"), "{text}");
    }
}
