//! The [`Scheduler`] trait and the four built-in scheduling disciplines.
//!
//! A scheduler owns the ordering of pending [`WorkItem`]s — single network
//! evaluations — and decides which request's work the engine packs into the
//! next batch. The engine is the only caller: it `push`es the items of a
//! request's current step (all slots, back-to-back, in slot order) together
//! with a fresh [`RequestMeta`] snapshot, asks `peek_model` which model the
//! next batch should run, and `take_batch`es up to the backend's bucket
//! capacity. When a request completes, `forget` drops any per-request
//! bookkeeping.
//!
//! The cost signal: `RequestMeta::remaining_nfes` is the engine's *current*
//! estimate of the evaluations the request still needs — the policy's plan
//! sequence under its live [`PolicyState`](crate::PolicyState). Because the
//! engine re-pushes with a fresh snapshot every step, an AG truncation
//! (which halves the per-step cost) reaches the scheduler the step after
//! `observe` fires, exactly when the remaining work actually shrinks.
//!
//! Disciplines:
//!  * [`Fifo`] — strict arrival order; bit-for-bit the engine's historical
//!    behaviour, and the default.
//!  * [`CostAware`] — shortest-remaining-NFE-first (SRPT on the cost
//!    estimate). Under mixed cfg/ag traffic this keeps cheap truncated
//!    requests from queueing behind expensive full-CFG ones, which is where
//!    FIFO's tail latency comes from.
//!  * [`Deadline`] — earliest-deadline-first over the optional per-request
//!    `deadline_ms`, ties broken by higher `priority`, then arrival id.
//!    Requests without a deadline sort last.
//!  * [`FairShare`] — round-robin across `client_id` lanes so one bulk
//!    client cannot starve interactive ones; within a lane, FIFO.

use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::sync::Arc;

/// One pending network evaluation: a slot of some request's current step.
#[derive(Debug, Clone)]
pub struct WorkItem {
    /// Engine slot index of the owning request's state.
    pub state_idx: usize,
    /// Eval slot within the step's plan.
    pub slot: usize,
    /// Backend model the eval runs on (interned — clones are refcounts).
    pub model: Arc<str>,
}

/// Per-request scheduling facts, snapshotted by the engine at push time.
#[derive(Debug, Clone)]
pub struct RequestMeta {
    /// Request id (arrival order under a serving front-end) — the ultimate
    /// deterministic tie-breaker.
    pub id: u64,
    /// Client/connection identity for fair-share lanes ("" = anonymous).
    pub client: Arc<str>,
    /// Larger = more important; ties under [`Deadline`].
    pub priority: i32,
    /// Optional absolute deadline in engine-clock milliseconds (the
    /// engine anchors the request's arrival-relative deadline at
    /// admission, so values from different requests are comparable).
    pub deadline_ms: Option<u64>,
    /// Current estimate of evaluations this request still needs (see
    /// module docs).
    pub remaining_nfes: usize,
}

/// Ordering discipline over pending work items (see module docs).
///
/// Contract: `push` is called with every item of a step before the engine
/// pumps again; `take_batch(model, cap, out)` must only append items whose
/// `model` matches and at most `cap` of them; `forget` is called once per
/// completed request, after all its items have been taken.
///
/// §Perf: `take_batch` appends into a caller-owned buffer (the engine
/// reuses one across pumps) and implementations keep their own scratch, so
/// a steady-state batch pop performs no heap allocation — pinned by
/// `rust/tests/zero_alloc.rs` for all four built-ins.
pub trait Scheduler: fmt::Debug + Send {
    /// Wire name (matches [`SchedulerKind::parse`]).
    fn name(&self) -> &'static str;

    /// Enqueue one work item with a fresh snapshot of its request's meta.
    fn push(&mut self, item: WorkItem, meta: &RequestMeta);

    /// Model of the batch this scheduler would execute next (None = empty).
    fn peek_model(&self) -> Option<Arc<str>>;

    /// Remove up to `cap` items of `model`, appending them to `out` in
    /// scheduling order (the caller clears `out` beforehand).
    fn take_batch(&mut self, model: &str, cap: usize, out: &mut Vec<WorkItem>);

    /// Drop per-request bookkeeping after the request completes.
    fn forget(&mut self, _state_idx: usize) {}

    /// §Robustness: remove every *queued* item of `state_idx` and drop
    /// its bookkeeping — the salvage path. Unlike [`Scheduler::forget`]
    /// (called only after all of a request's items have been taken),
    /// `revoke` fires while items may still be queued, so queue-holding
    /// implementations must override it to actually drop them; the
    /// default only forgets, which would orphan queued items.
    fn revoke(&mut self, state_idx: usize) {
        self.forget(state_idx);
    }

    /// Pending item count.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Scheduler selection for configs/CLI (`--scheduler` on `agd serve`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    Fifo,
    CostAware,
    Deadline,
    FairShare,
}

impl SchedulerKind {
    /// Every selectable kind, in display order.
    pub const ALL: [SchedulerKind; 4] = [
        SchedulerKind::Fifo,
        SchedulerKind::CostAware,
        SchedulerKind::Deadline,
        SchedulerKind::FairShare,
    ];

    /// Wire name.
    pub fn name(self) -> &'static str {
        match self {
            SchedulerKind::Fifo => "fifo",
            SchedulerKind::CostAware => "cost-aware",
            SchedulerKind::Deadline => "deadline",
            SchedulerKind::FairShare => "fair-share",
        }
    }

    /// Parse a wire name; the error lists the valid names.
    pub fn parse(text: &str) -> Result<SchedulerKind, String> {
        SchedulerKind::ALL
            .into_iter()
            .find(|k| k.name() == text)
            .ok_or_else(|| {
                let names: Vec<&str> = SchedulerKind::ALL.iter().map(|k| k.name()).collect();
                format!("unknown scheduler `{text}` (valid: {})", names.join(", "))
            })
    }

    /// Construct a fresh scheduler of this kind.
    pub fn build(self) -> Box<dyn Scheduler> {
        match self {
            SchedulerKind::Fifo => Box::new(Fifo::default()),
            SchedulerKind::CostAware => Box::new(CostAware::default()),
            SchedulerKind::Deadline => Box::new(Deadline::default()),
            SchedulerKind::FairShare => Box::new(FairShare::default()),
        }
    }
}

// ---------------------------------------------------------------------------
// Fifo
// ---------------------------------------------------------------------------

/// Strict arrival order — the engine's historical behaviour and the
/// default. With it, completions are byte-identical to the pre-scheduler
/// engine (the determinism tests pin this).
#[derive(Debug, Default)]
pub struct Fifo {
    queue: VecDeque<WorkItem>,
}

impl Scheduler for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn push(&mut self, item: WorkItem, _meta: &RequestMeta) {
        self.queue.push_back(item);
    }

    fn peek_model(&self) -> Option<Arc<str>> {
        self.queue.front().map(|it| it.model.clone())
    }

    fn take_batch(&mut self, model: &str, cap: usize, out: &mut Vec<WorkItem>) {
        // remove the first `cap` items of `model` in place, preserving the
        // relative order of everything left behind (clone = one Arc bump)
        let mut taken = 0usize;
        self.queue.retain(|it| {
            if taken < cap && &*it.model == model {
                out.push(it.clone());
                taken += 1;
                false
            } else {
                true
            }
        });
    }

    fn revoke(&mut self, state_idx: usize) {
        self.queue.retain(|it| it.state_idx != state_idx);
    }

    fn len(&self) -> usize {
        self.queue.len()
    }
}

// ---------------------------------------------------------------------------
// Ranked: shared core of CostAware and Deadline
// ---------------------------------------------------------------------------

/// Items in push order plus one orderable key per request; batches are the
/// `cap` matching items with the smallest keys (ties break by push order,
/// which keeps a step's slots adjacent). O(n log n) per batch, which is
/// ample at serving queue depths. Selection runs on a reusable index
/// scratch and compacts `items` in place — no allocation at steady state.
#[derive(Debug, Default)]
struct Ranked<K: Ord + Copy + fmt::Debug> {
    items: Vec<WorkItem>,
    keys: HashMap<usize, K>,
    /// selected-index scratch reused across `take_batch` calls
    scratch: Vec<usize>,
}

impl<K: Ord + Copy + fmt::Debug> Ranked<K> {
    fn push(&mut self, item: WorkItem, key: K) {
        self.keys.insert(item.state_idx, key);
        self.items.push(item);
    }

    fn key_of(&self, it: &WorkItem) -> K {
        *self
            .keys
            .get(&it.state_idx)
            .expect("scheduler invariant: every queued item has a key")
    }

    fn peek_model(&self) -> Option<Arc<str>> {
        let mut best: Option<(K, &WorkItem)> = None;
        for it in &self.items {
            let k = self.key_of(it);
            // strict `<` keeps the first occurrence (push order) on ties
            if best.map_or(true, |(bk, _)| k < bk) {
                best = Some((k, it));
            }
        }
        best.map(|(_, it)| it.model.clone())
    }

    fn take_batch(&mut self, model: &str, cap: usize, out: &mut Vec<WorkItem>) {
        let items = &self.items;
        let keys = &self.keys;
        self.scratch.clear();
        self.scratch
            .extend((0..items.len()).filter(|&i| &*items[i].model == model));
        // the item index is the final sort component, so the unstable sort
        // reproduces a stable sort on the key alone (push order on ties)
        self.scratch.sort_unstable_by_key(|&i| {
            let k = *keys
                .get(&items[i].state_idx)
                .expect("scheduler invariant: every queued item has a key");
            (k, i)
        });
        self.scratch.truncate(cap);
        for &i in &self.scratch {
            out.push(self.items[i].clone());
        }
        // compact `items` in place, dropping the taken indices
        self.scratch.sort_unstable();
        let mut next_taken = 0usize;
        let mut write = 0usize;
        for read in 0..self.items.len() {
            if next_taken < self.scratch.len() && self.scratch[next_taken] == read {
                next_taken += 1;
                continue;
            }
            self.items.swap(write, read);
            write += 1;
        }
        self.items.truncate(write);
    }

    fn forget(&mut self, state_idx: usize) {
        self.keys.remove(&state_idx);
    }

    fn revoke(&mut self, state_idx: usize) {
        self.items.retain(|it| it.state_idx != state_idx);
        self.keys.remove(&state_idx);
    }

    fn len(&self) -> usize {
        self.items.len()
    }
}

// ---------------------------------------------------------------------------
// CostAware
// ---------------------------------------------------------------------------

/// Shortest-remaining-NFE-first: order requests by the engine's live
/// remaining-cost estimate, arrival id on ties. The estimate tightens the
/// moment a policy's `observe` truncates a request (see module docs), so
/// AG-truncated requests jump ahead of full-CFG ones mid-flight.
#[derive(Debug, Default)]
pub struct CostAware {
    inner: Ranked<(usize, u64)>,
}

impl Scheduler for CostAware {
    fn name(&self) -> &'static str {
        "cost-aware"
    }

    fn push(&mut self, item: WorkItem, meta: &RequestMeta) {
        self.inner.push(item, (meta.remaining_nfes, meta.id));
    }

    fn peek_model(&self) -> Option<Arc<str>> {
        self.inner.peek_model()
    }

    fn take_batch(&mut self, model: &str, cap: usize, out: &mut Vec<WorkItem>) {
        self.inner.take_batch(model, cap, out)
    }

    fn forget(&mut self, state_idx: usize) {
        self.inner.forget(state_idx);
    }

    fn revoke(&mut self, state_idx: usize) {
        self.inner.revoke(state_idx);
    }

    fn len(&self) -> usize {
        self.inner.len()
    }
}

// ---------------------------------------------------------------------------
// Deadline
// ---------------------------------------------------------------------------

/// Earliest-deadline-first. Requests without a deadline sort after every
/// dated one; ties go to the higher `priority`, then the earlier arrival.
#[derive(Debug, Default)]
pub struct Deadline {
    inner: Ranked<(u64, std::cmp::Reverse<i32>, u64)>,
}

impl Scheduler for Deadline {
    fn name(&self) -> &'static str {
        "deadline"
    }

    fn push(&mut self, item: WorkItem, meta: &RequestMeta) {
        let key = (
            meta.deadline_ms.unwrap_or(u64::MAX),
            std::cmp::Reverse(meta.priority),
            meta.id,
        );
        self.inner.push(item, key);
    }

    fn peek_model(&self) -> Option<Arc<str>> {
        self.inner.peek_model()
    }

    fn take_batch(&mut self, model: &str, cap: usize, out: &mut Vec<WorkItem>) {
        self.inner.take_batch(model, cap, out)
    }

    fn forget(&mut self, state_idx: usize) {
        self.inner.forget(state_idx);
    }

    fn revoke(&mut self, state_idx: usize) {
        self.inner.revoke(state_idx);
    }

    fn len(&self) -> usize {
        self.inner.len()
    }
}

// ---------------------------------------------------------------------------
// FairShare
// ---------------------------------------------------------------------------

/// Most client lanes retained after draining. A drained lane is kept (its
/// deque capacity ready for the client's next step — the steady-state
/// zero-alloc path) until the lane count exceeds this cap, at which point
/// drained lanes are pruned so an open-ended client-id stream cannot grow
/// the scheduler without bound. Mirrors telemetry's `LABEL_VALUE_CAP`.
const LANE_CAP: usize = 64;

/// Round-robin across client lanes: each batch slot goes to the next lane
/// in rotation whose front item matches the batch model, so a client's
/// share of a full batch is at most ⌈cap / active clients⌉ while others
/// have work queued. Lanes are FIFO internally; drained lanes are kept for
/// reuse up to [`LANE_CAP`] and pruned beyond it.
#[derive(Debug, Default)]
pub struct FairShare {
    /// (client, lane) in first-seen order — the rotation order.
    lanes: Vec<(Arc<str>, VecDeque<WorkItem>)>,
    /// Rotation position: the lane the next batch starts taking from.
    cursor: usize,
}

impl Scheduler for FairShare {
    fn name(&self) -> &'static str {
        "fair-share"
    }

    fn push(&mut self, item: WorkItem, meta: &RequestMeta) {
        match self.lanes.iter_mut().find(|(c, _)| *c == meta.client) {
            Some((_, lane)) => lane.push_back(item),
            None => {
                let mut lane = VecDeque::new();
                lane.push_back(item);
                self.lanes.push((meta.client.clone(), lane));
            }
        }
    }

    fn peek_model(&self) -> Option<Arc<str>> {
        let n = self.lanes.len();
        (0..n)
            .map(|i| &self.lanes[(self.cursor + i) % n].1)
            .find_map(|lane| lane.front().map(|it| it.model.clone()))
    }

    fn take_batch(&mut self, model: &str, cap: usize, out: &mut Vec<WorkItem>) {
        let n = self.lanes.len();
        if n == 0 {
            return;
        }
        let mut taken = 0usize;
        let mut pos = self.cursor;
        let mut barren = 0; // consecutive lanes that contributed nothing
        while taken < cap && barren < n {
            let lane = &mut self.lanes[pos % n].1;
            if lane.front().map_or(false, |it| &*it.model == model) {
                out.push(lane.pop_front().expect("front just checked"));
                taken += 1;
                barren = 0;
            } else {
                barren += 1;
            }
            pos += 1;
        }
        self.cursor = pos % n;
        // drained lanes stay for reuse (the rotation skips them) until the
        // lane count exceeds the cap; past it, prune and remap the cursor
        self.prune_lanes();
    }

    fn revoke(&mut self, state_idx: usize) {
        for (_, lane) in &mut self.lanes {
            lane.retain(|it| it.state_idx != state_idx);
        }
    }

    fn len(&self) -> usize {
        self.lanes.iter().map(|(_, lane)| lane.len()).sum()
    }
}

impl FairShare {
    /// Prune drained lanes once the lane count exceeds [`LANE_CAP`],
    /// remapping the rotation cursor past the removals.
    fn prune_lanes(&mut self) {
        if self.lanes.len() > LANE_CAP {
            let cursor_lane = self.cursor;
            let mut new_cursor = 0;
            let mut kept = Vec::with_capacity(self.lanes.len());
            for (i, lane) in std::mem::take(&mut self.lanes).into_iter().enumerate() {
                if !lane.1.is_empty() {
                    if i < cursor_lane {
                        new_cursor += 1;
                    }
                    kept.push(lane);
                }
            }
            self.lanes = kept;
            self.cursor = if self.lanes.is_empty() {
                0
            } else {
                new_cursor % self.lanes.len()
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn item(state_idx: usize, slot: usize, model: &str) -> WorkItem {
        WorkItem {
            state_idx,
            slot,
            model: Arc::from(model),
        }
    }

    fn meta(id: u64, client: &str, remaining: usize) -> RequestMeta {
        RequestMeta {
            id,
            client: Arc::from(client),
            priority: 0,
            deadline_ms: None,
            remaining_nfes: remaining,
        }
    }

    /// Push a two-slot step for one request.
    fn push_step(s: &mut dyn Scheduler, idx: usize, m: &RequestMeta) {
        s.push(item(idx, 0, "gmm"), m);
        s.push(item(idx, 1, "gmm"), m);
    }

    /// Owned-vec convenience over the out-buffer `take_batch` form.
    fn take(s: &mut dyn Scheduler, model: &str, cap: usize) -> Vec<WorkItem> {
        let mut out = Vec::new();
        s.take_batch(model, cap, &mut out);
        out
    }

    #[test]
    fn kind_parse_round_trips() {
        for k in SchedulerKind::ALL {
            assert_eq!(SchedulerKind::parse(k.name()), Ok(k));
            assert_eq!(k.build().name(), k.name());
        }
        let err = SchedulerKind::parse("lifo").unwrap_err();
        assert!(err.contains("fifo") && err.contains("cost-aware"), "{err}");
    }

    #[test]
    fn fifo_preserves_arrival_order_and_model_affinity() {
        let mut s = Fifo::default();
        s.push(item(0, 0, "a"), &meta(0, "", 2));
        s.push(item(1, 0, "b"), &meta(1, "", 2));
        s.push(item(2, 0, "a"), &meta(2, "", 2));
        assert_eq!(&*s.peek_model().unwrap(), "a");
        let batch = take(&mut s, "a", 8);
        assert_eq!(batch.len(), 2);
        assert_eq!((batch[0].state_idx, batch[1].state_idx), (0, 2));
        // the non-matching item stays, in order
        assert_eq!(s.len(), 1);
        assert_eq!(&*s.peek_model().unwrap(), "b");
    }

    #[test]
    fn fifo_cap_leaves_overflow_in_order() {
        let mut s = Fifo::default();
        for i in 0..5 {
            s.push(item(i, 0, "m"), &meta(i as u64, "", 1));
        }
        let batch = take(&mut s, "m", 3);
        assert_eq!(batch.iter().map(|it| it.state_idx).collect::<Vec<_>>(), vec![0, 1, 2]);
        let batch = take(&mut s, "m", 3);
        assert_eq!(batch.iter().map(|it| it.state_idx).collect::<Vec<_>>(), vec![3, 4]);
        assert!(s.is_empty());
    }

    #[test]
    fn cost_aware_orders_by_remaining_then_id() {
        let mut s = CostAware::default();
        push_step(&mut s, 0, &meta(0, "", 40)); // expensive
        push_step(&mut s, 1, &meta(1, "", 12)); // cheap
        push_step(&mut s, 2, &meta(2, "", 12)); // cheap, later id
        let batch = take(&mut s, "gmm", 4);
        let order: Vec<usize> = batch.iter().map(|it| it.state_idx).collect();
        assert_eq!(order, vec![1, 1, 2, 2], "cheapest first, id breaks ties");
        // slots of one request stay adjacent and in slot order
        assert_eq!((batch[0].slot, batch[1].slot), (0, 1));
    }

    #[test]
    fn cost_aware_repush_updates_the_estimate() {
        let mut s = CostAware::default();
        push_step(&mut s, 0, &meta(0, "", 40));
        push_step(&mut s, 1, &meta(1, "", 30));
        // request 0 truncated: its next step is pushed with a lower estimate
        assert_eq!(take(&mut s, "gmm", 4).len(), 4);
        s.push(item(0, 0, "gmm"), &meta(0, "", 8));
        push_step(&mut s, 1, &meta(1, "", 28));
        let batch = take(&mut s, "gmm", 1);
        assert_eq!(batch[0].state_idx, 0, "truncated request now schedules first");
        s.forget(0);
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn deadline_is_edf_with_priority_ties() {
        let mut s = Deadline::default();
        let mut m0 = meta(0, "", 2);
        m0.deadline_ms = None; // undated → last
        let mut m1 = meta(1, "", 2);
        m1.deadline_ms = Some(500);
        let mut m2 = meta(2, "", 2);
        m2.deadline_ms = Some(100);
        let mut m3 = meta(3, "", 2);
        m3.deadline_ms = Some(100);
        m3.priority = 5; // same deadline, more important
        for (i, m) in [(0usize, &m0), (1, &m1), (2, &m2), (3, &m3)] {
            s.push(item(i, 0, "gmm"), m);
        }
        let order: Vec<usize> = take(&mut s, "gmm", 8).iter().map(|it| it.state_idx).collect();
        assert_eq!(order, vec![3, 2, 1, 0]);
    }

    #[test]
    fn fair_share_round_robins_across_clients() {
        let mut s = FairShare::default();
        // bulk floods 6 items before interactive's 2 arrive
        for i in 0..6 {
            s.push(item(i, 0, "gmm"), &meta(i as u64, "bulk", 2));
        }
        for i in 6..8 {
            s.push(item(i, 0, "gmm"), &meta(i as u64, "live", 2));
        }
        let batch = take(&mut s, "gmm", 4);
        let order: Vec<usize> = batch.iter().map(|it| it.state_idx).collect();
        // alternating lanes: bulk, live, bulk, live
        assert_eq!(order, vec![0, 6, 1, 7]);
        // live lane drained → the rest is all bulk
        let batch = take(&mut s, "gmm", 8);
        let order: Vec<usize> = batch.iter().map(|it| it.state_idx).collect();
        assert_eq!(order, vec![2, 3, 4, 5]);
        assert!(s.is_empty());
    }

    #[test]
    fn fair_share_bounds_a_client_share_per_batch() {
        let mut s = FairShare::default();
        for i in 0..16 {
            s.push(item(i, 0, "gmm"), &meta(i as u64, "bulk", 2));
        }
        for i in 16..20 {
            s.push(item(i, 0, "gmm"), &meta(i as u64, "live", 2));
        }
        let batch = take(&mut s, "gmm", 8);
        let live = batch.iter().filter(|it| it.state_idx >= 16).count();
        assert_eq!(live, 4, "live client gets a full interleaved share");
    }

    #[test]
    fn empty_schedulers_are_quiet() {
        for kind in SchedulerKind::ALL {
            let mut s = kind.build();
            assert!(s.peek_model().is_none(), "{}", s.name());
            assert!(take(&mut s, "gmm", 4).is_empty());
            assert_eq!(s.len(), 0);
            s.forget(3); // unknown request: no-op, no panic
            s.revoke(3); // same for the salvage path
        }
    }

    /// §Robustness: `revoke` pulls *queued* items back out under every
    /// discipline — unlike `forget`, which only drops bookkeeping. The
    /// fleet's shard-death salvage depends on this: a revoked request
    /// must leave no orphaned items that a later batch could take.
    #[test]
    fn revoke_removes_queued_items_under_every_discipline() {
        for kind in SchedulerKind::ALL {
            let mut s = kind.build();
            for idx in 0..3usize {
                let mut m = meta(idx as u64, if idx == 1 { "live" } else { "bulk" }, 10);
                m.deadline_ms = Some(100 + idx as u64);
                push_step(s.as_mut(), idx, &m);
            }
            assert_eq!(s.len(), 6, "{}", s.name());
            s.revoke(1);
            assert_eq!(s.len(), 4, "{}", s.name());
            // the survivors drain normally and never include the revoked
            // request (a Ranked orphan would panic in key_of here)
            let batch = take(s.as_mut(), "gmm", 8);
            assert_eq!(batch.len(), 4, "{}", s.name());
            assert!(
                batch.iter().all(|it| it.state_idx != 1),
                "{}: revoked items resurfaced",
                s.name()
            );
            assert!(s.is_empty(), "{}", s.name());
        }
    }
}
