//! The denoiser execution interface between the coordinator (L3) and the
//! compute substrate.
//!
//! Two implementations:
//!  * [`runtime::PjrtBackend`](crate::runtime) — the production path: AOT'd
//!    HLO executables (DiT + Pallas kernels) on the PJRT CPU client.
//!  * [`GmmBackend`] — the analytic Gaussian-mixture oracle
//!    ([`sim::gmm`](crate::sim::gmm)): exact scores, no artifacts needed.
//!    Coordinator unit/property tests and scheduler stress tests run on it.

use anyhow::Result;

use crate::sim::gmm::Gmm;

/// One denoiser evaluation request: a single NFE's inputs.
#[derive(Debug, Clone)]
pub struct EvalInput {
    /// flattened latent (length = `flat_in(model)`)
    pub x: Vec<f32>,
    /// continuous time in [0, 1]
    pub t: f32,
    /// condition tokens (all-zero = unconditional)
    pub tokens: Vec<i32>,
}

/// Batched denoiser execution.
///
/// Not `Send`: the PJRT client wraps thread-affine host state, so the
/// serving front-end constructs its backend *inside* the engine thread (see
/// `server::serve`'s factory parameter).
pub trait Backend {
    /// Flattened *input* latent length for `model` (editing models take
    /// `2 * flat_out`: latent ‖ source image).
    fn flat_in(&self, model: &str) -> usize;

    /// Flattened *output* score length for `model`.
    fn flat_out(&self, model: &str) -> usize;

    /// Batch-size buckets this backend can execute, ascending.
    fn buckets(&self) -> &[usize];

    /// Largest batch executable for `model` (defaults to the global max;
    /// models lowered with fewer buckets — e.g. the editing model — cap
    /// lower, and the scheduler packs per-model accordingly).
    fn max_batch(&self, _model: &str) -> usize {
        *self.buckets().last().expect("backend has no buckets")
    }

    /// Execute one batch of evaluations (`items.len() <= max bucket`);
    /// returns one flat score vector per item, in order.
    fn denoise(&mut self, model: &str, items: &[EvalInput]) -> Result<Vec<Vec<f32>>>;

    /// Available model names.
    fn models(&self) -> Vec<String>;
}

/// Analytic GMM backend (test substrate). Token slot 0 selects the mixture
/// component (1-based; 0 = unconditional), mirroring the shapes vocabulary.
pub struct GmmBackend {
    pub gmm: Gmm,
    buckets: Vec<usize>,
    /// number of denoise() calls (lets tests assert batching behaviour)
    pub calls: usize,
    /// total items executed
    pub items_executed: usize,
}

impl GmmBackend {
    pub fn new(gmm: Gmm) -> GmmBackend {
        GmmBackend {
            gmm,
            buckets: vec![1, 2, 4, 8, 16],
            calls: 0,
            items_executed: 0,
        }
    }

    pub fn with_buckets(mut self, buckets: Vec<usize>) -> GmmBackend {
        assert!(!buckets.is_empty());
        self.buckets = buckets;
        self
    }
}

impl Backend for GmmBackend {
    fn flat_in(&self, _model: &str) -> usize {
        self.gmm.dim
    }

    fn flat_out(&self, _model: &str) -> usize {
        self.gmm.dim
    }

    fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn denoise(&mut self, _model: &str, items: &[EvalInput]) -> Result<Vec<Vec<f32>>> {
        let max = *self.buckets.last().unwrap();
        anyhow::ensure!(
            items.len() <= max,
            "batch {} exceeds max bucket {max}",
            items.len()
        );
        self.calls += 1;
        self.items_executed += items.len();
        Ok(items
            .iter()
            .map(|it| {
                let cond = if it.tokens[0] == 0 {
                    None
                } else {
                    Some((it.tokens[0] - 1) as usize)
                };
                self.gmm.eps(&it.x, it.t as f64, cond)
            })
            .collect())
    }

    fn models(&self) -> Vec<String> {
        vec!["gmm".to_owned()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gmm_backend_routes_condition_tokens() {
        let mut be = GmmBackend::new(Gmm::axes(4, 2, 2.0, 0.1));
        let x = vec![0.5f32; 4];
        let mk = |tok: i32| EvalInput {
            x: x.clone(),
            t: 0.5,
            tokens: vec![tok, 0, 0, 0],
        };
        let out = be.denoise("gmm", &[mk(0), mk(1), mk(2)]).unwrap();
        assert_eq!(out.len(), 3);
        // conditional scores for different components differ; both differ
        // from the unconditional mixture score.
        assert_ne!(out[1], out[2]);
        assert_ne!(out[0], out[1]);
        assert_eq!(be.calls, 1);
        assert_eq!(be.items_executed, 3);
    }

    #[test]
    fn gmm_backend_rejects_oversized_batch() {
        let mut be =
            GmmBackend::new(Gmm::axes(4, 2, 2.0, 0.1)).with_buckets(vec![1, 2]);
        let items: Vec<EvalInput> = (0..3)
            .map(|_| EvalInput {
                x: vec![0.0; 4],
                t: 0.5,
                tokens: vec![0; 4],
            })
            .collect();
        assert!(be.denoise("gmm", &items).is_err());
    }
}
