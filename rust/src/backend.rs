//! The denoiser execution interface between the coordinator (L3) and the
//! compute substrate.
//!
//! Two implementations:
//!  * [`runtime::PjrtBackend`](crate::runtime) — the production path: AOT'd
//!    HLO executables (DiT + Pallas kernels) on the PJRT CPU client.
//!  * [`GmmBackend`] — the analytic Gaussian-mixture oracle
//!    ([`sim::gmm`](crate::sim::gmm)): exact scores, no artifacts needed.
//!    Coordinator unit/property tests and scheduler stress tests run on it.
//!
//! # Packed batches (§Perf)
//!
//! The primary execution form is [`Backend::denoise_into`] over a
//! [`BatchBuf`]/[`BatchOut`] pair: one contiguous row-major `batch ×
//! flat_in` latent buffer with parallel time/token tables in, one
//! contiguous `batch × flat_out` score buffer out. Both buffers are
//! engine-owned and reused across calls (`reset` keeps capacity), so a
//! steady-state serving loop executes batches without touching the heap.
//! The per-item [`Backend::denoise`] form survives as a default-method
//! compatibility wrapper for external backends and offline callers.

use anyhow::Result;

use crate::exec::{ExecPool, RowShards, RunStats, SliceShards};
use crate::sim::gmm::{Gmm, GmmScratch};

/// One denoiser evaluation request: a single NFE's inputs. Compatibility
/// form — the engine's hot path packs rows into a [`BatchBuf`] instead.
#[derive(Debug, Clone)]
pub struct EvalInput {
    /// flattened latent (length = `flat_in(model)`)
    pub x: Vec<f32>,
    /// continuous time in [0, 1]
    pub t: f32,
    /// condition tokens (all-zero = unconditional)
    pub tokens: Vec<i32>,
}

/// A packed batch of denoiser inputs: a contiguous row-major
/// `len × flat_in` latent buffer plus parallel per-row time and token
/// tables. Reusable — [`BatchBuf::reset`] clears rows but keeps capacity,
/// so the engine fills the same allocation every pump.
#[derive(Debug, Default)]
pub struct BatchBuf {
    xs: Vec<f32>,
    ts: Vec<f32>,
    tokens: Vec<i32>,
    flat_in: usize,
    tok_width: usize,
    len: usize,
}

impl BatchBuf {
    pub fn new(flat_in: usize, tok_width: usize) -> BatchBuf {
        let mut b = BatchBuf::default();
        b.reset(flat_in, tok_width);
        b
    }

    /// Drop all rows and set the row geometry; capacity is retained.
    pub fn reset(&mut self, flat_in: usize, tok_width: usize) {
        self.xs.clear();
        self.ts.clear();
        self.tokens.clear();
        self.flat_in = flat_in;
        self.tok_width = tok_width;
        self.len = 0;
    }

    /// Append one zeroed row at time `t`; returns mutable views of its
    /// latent and token slots for the caller to fill in place.
    pub fn push_row(&mut self, t: f32) -> (&mut [f32], &mut [i32]) {
        let x0 = self.xs.len();
        self.xs.resize(x0 + self.flat_in, 0.0);
        let k0 = self.tokens.len();
        self.tokens.resize(k0 + self.tok_width, 0);
        self.ts.push(t);
        self.len += 1;
        (&mut self.xs[x0..], &mut self.tokens[k0..])
    }

    /// Number of rows.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Row-major latent length per row.
    pub fn flat_in(&self) -> usize {
        self.flat_in
    }

    /// Token slots per row.
    pub fn tok_width(&self) -> usize {
        self.tok_width
    }

    /// Latent row `i`.
    pub fn x_row(&self, i: usize) -> &[f32] {
        &self.xs[i * self.flat_in..(i + 1) * self.flat_in]
    }

    /// Time of row `i`.
    pub fn t(&self, i: usize) -> f32 {
        self.ts[i]
    }

    /// Token row `i`.
    pub fn token_row(&self, i: usize) -> &[i32] {
        &self.tokens[i * self.tok_width..(i + 1) * self.tok_width]
    }

    /// The whole packed latent buffer (`len * flat_in`).
    pub fn xs(&self) -> &[f32] {
        &self.xs
    }

    /// The packed time table (`len`).
    pub fn ts(&self) -> &[f32] {
        &self.ts
    }

    /// The packed token table (`len * tok_width`).
    pub fn tokens(&self) -> &[i32] {
        &self.tokens
    }
}

/// A packed batch of denoiser outputs: one contiguous row-major
/// `len × flat_out` score buffer, reused across calls like [`BatchBuf`].
#[derive(Debug, Default)]
pub struct BatchOut {
    data: Vec<f32>,
    flat_out: usize,
    len: usize,
}

impl BatchOut {
    /// Size for `len` rows of `flat_out` zeros; capacity is retained.
    /// Rows are deliberately zeroed (one linear pass, trivial next to a
    /// denoiser NFE) so a backend that under-writes can never leak a stale
    /// row from a previous, larger batch.
    pub fn reset(&mut self, flat_out: usize, len: usize) {
        self.flat_out = flat_out;
        self.len = len;
        self.data.clear();
        self.data.resize(flat_out * len, 0.0);
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn flat_out(&self) -> usize {
        self.flat_out
    }

    /// Score row `i`.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.flat_out..(i + 1) * self.flat_out]
    }

    /// Mutable score row `i` (backends write results here).
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.flat_out..(i + 1) * self.flat_out]
    }

    /// The whole packed buffer (`len * flat_out`).
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable view of the whole packed buffer.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }
}

/// Batched denoiser execution.
///
/// Not `Send`: the PJRT client wraps thread-affine host state, so the
/// serving front-end constructs its backend *inside* the engine thread (see
/// `server::serve`'s factory parameter).
pub trait Backend {
    /// Flattened *input* latent length for `model` (editing models take
    /// `2 * flat_out`: latent ‖ source image).
    fn flat_in(&self, model: &str) -> usize;

    /// Flattened *output* score length for `model`.
    fn flat_out(&self, model: &str) -> usize;

    /// Batch-size buckets this backend can execute, ascending.
    fn buckets(&self) -> &[usize];

    /// Largest batch executable for `model` (defaults to the global max;
    /// models lowered with fewer buckets — e.g. the editing model — cap
    /// lower, and the scheduler packs per-model accordingly).
    fn max_batch(&self, _model: &str) -> usize {
        *self.buckets().last().expect("backend has no buckets")
    }

    /// Validate one request's token row for `model` before admission.
    /// Backends with a fixed vocabulary or token width override this so
    /// the serving front door can refuse requests that would
    /// deterministically fail mid-batch (the engine maps the reason to a
    /// structured `invalid_request` rejection). The default accepts
    /// everything.
    fn validate_tokens(&self, _model: &str, _tokens: &[i32]) -> Result<(), &'static str> {
        Ok(())
    }

    /// Execute one packed batch (`batch.len() <= max bucket`): size `out`
    /// to `batch.len()` rows of `flat_out(model)` and write one score row
    /// per input row. The caller owns and reuses both buffers across calls;
    /// implementations must not retain references into them.
    fn denoise_into(&mut self, model: &str, batch: &BatchBuf, out: &mut BatchOut) -> Result<()>;

    /// [`Self::denoise_into`] with an [`ExecPool`] offered for sharding
    /// the batch rows across worker lanes — the engine's execution entry
    /// point. The default ignores the pool and runs the serial path,
    /// which is the right behaviour for thread-affine backends (the PJRT
    /// client is not `Send` and must stay on the engine thread); it
    /// reports `None` so the engine's worker gauges fall back to its own
    /// parallel regions. Host-math backends that do override it must keep
    /// per-row results bit-identical to the serial path for any lane
    /// count: shard strictly *across* rows, never the math *within* one.
    fn denoise_into_par(
        &mut self,
        model: &str,
        batch: &BatchBuf,
        out: &mut BatchOut,
        exec: &ExecPool,
    ) -> Result<Option<RunStats>> {
        let _ = exec;
        self.denoise_into(model, batch, out)?;
        Ok(None)
    }

    /// Per-item compatibility wrapper over [`Backend::denoise_into`]:
    /// packs `items` into a fresh [`BatchBuf`] (token rows sized by the
    /// widest item; narrower rows zero-pad their tail, the all-zero =
    /// unconditional convention) and splits the result rows back into
    /// owned vectors. Allocates per call — offline tools and external
    /// backends only; the engine never takes this path.
    fn denoise(&mut self, model: &str, items: &[EvalInput]) -> Result<Vec<Vec<f32>>> {
        let flat_in = self.flat_in(model);
        let tok_width = items.iter().map(|it| it.tokens.len()).max().unwrap_or(0);
        let mut batch = BatchBuf::new(flat_in, tok_width);
        for it in items {
            anyhow::ensure!(
                it.x.len() == flat_in,
                "item latent length {} != flat_in {flat_in} for model {model}",
                it.x.len()
            );
            let (x, toks) = batch.push_row(it.t);
            x.copy_from_slice(&it.x);
            toks[..it.tokens.len()].copy_from_slice(&it.tokens);
        }
        let mut out = BatchOut::default();
        self.denoise_into(model, &batch, &mut out)?;
        Ok((0..batch.len()).map(|i| out.row(i).to_vec()).collect())
    }

    /// Available model names.
    fn models(&self) -> Vec<String>;
}

/// Analytic GMM backend (test substrate). Token slot 0 selects the mixture
/// component (1-based; 0 = unconditional), mirroring the shapes vocabulary.
pub struct GmmBackend {
    pub gmm: Gmm,
    buckets: Vec<usize>,
    /// number of batch executions (lets tests assert batching behaviour)
    pub calls: usize,
    /// total items executed
    pub items_executed: usize,
    /// responsibility scratch reused across every mixture-score row
    scratch: GmmScratch,
    /// one responsibility scratch per worker lane for the sharded path;
    /// grown (once) to the pool's lane count, then reused forever
    lane_scratch: Vec<GmmScratch>,
    /// per-row decoded conditions, staged serially before a sharded
    /// execution so token errors surface in row order (capacity retained)
    conds: Vec<Option<usize>>,
}

impl GmmBackend {
    pub fn new(gmm: Gmm) -> GmmBackend {
        GmmBackend {
            gmm,
            buckets: vec![1, 2, 4, 8, 16],
            calls: 0,
            items_executed: 0,
            scratch: GmmScratch::default(),
            lane_scratch: Vec::new(),
            conds: Vec::new(),
        }
    }

    pub fn with_buckets(mut self, buckets: Vec<usize>) -> GmmBackend {
        assert!(!buckets.is_empty());
        self.buckets = buckets;
        self
    }

    /// Decode a token row into the mixture condition, rejecting malformed
    /// rows (empty, or component index out of range) as structured errors
    /// rather than panicking mid-batch.
    fn cond_of(gmm: &Gmm, tokens: &[i32]) -> Result<Option<usize>> {
        let Some(&tok) = tokens.first() else {
            anyhow::bail!(
                "empty token row: the GMM backend reads token slot 0 as the \
                 mixture component (1-based; 0 = unconditional)"
            );
        };
        if tok == 0 {
            return Ok(None);
        }
        anyhow::ensure!(
            tok >= 1 && (tok as usize) <= gmm.components(),
            "condition token {tok} out of range: mixture has {} components \
             (tokens are 1-based; 0 = unconditional)",
            gmm.components()
        );
        Ok(Some((tok - 1) as usize))
    }

    /// Shared entry for both execution paths: bucket/geometry validation,
    /// call/item accounting, output sizing. Keeping this in one place
    /// guarantees the serial and sharded paths stay identical up to the
    /// row loop.
    fn stage_batch(&mut self, batch: &BatchBuf, out: &mut BatchOut) -> Result<()> {
        let max = *self.buckets.last().unwrap();
        anyhow::ensure!(
            batch.len() <= max,
            "batch {} exceeds max bucket {max}",
            batch.len()
        );
        anyhow::ensure!(
            batch.flat_in() == self.gmm.dim,
            "packed row length {} != gmm dim {}",
            batch.flat_in(),
            self.gmm.dim
        );
        self.calls += 1;
        self.items_executed += batch.len();
        out.reset(self.gmm.dim, batch.len());
        Ok(())
    }
}

impl Backend for GmmBackend {
    fn flat_in(&self, _model: &str) -> usize {
        self.gmm.dim
    }

    fn flat_out(&self, _model: &str) -> usize {
        self.gmm.dim
    }

    fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn validate_tokens(&self, _model: &str, tokens: &[i32]) -> Result<(), &'static str> {
        let Some(&tok) = tokens.first() else {
            return Err("tokens must be non-empty (slot 0 selects the mixture component)");
        };
        if tok != 0 && !(tok >= 1 && (tok as usize) <= self.gmm.components()) {
            return Err("condition token out of range for this model's component vocabulary");
        }
        Ok(())
    }

    fn denoise_into(&mut self, _model: &str, batch: &BatchBuf, out: &mut BatchOut) -> Result<()> {
        self.stage_batch(batch, out)?;
        for i in 0..batch.len() {
            let cond = Self::cond_of(&self.gmm, batch.token_row(i))?;
            self.gmm.eps_into(
                batch.x_row(i),
                batch.t(i) as f64,
                cond,
                out.row_mut(i),
                &mut self.scratch,
            );
        }
        Ok(())
    }

    /// §Perf: shard the packed rows across the pool's lanes. Each row is
    /// an independent mixture-score evaluation writing its own disjoint
    /// output row with its own lane-local [`GmmScratch`], and the per-row
    /// math is exactly [`Gmm::eps_into`] — so results are bit-identical
    /// to the serial path for any lane count. Token decoding stays serial
    /// (it is O(1) per row) so malformed rows error in row order, same as
    /// the serial path.
    fn denoise_into_par(
        &mut self,
        model: &str,
        batch: &BatchBuf,
        out: &mut BatchOut,
        exec: &ExecPool,
    ) -> Result<Option<RunStats>> {
        if exec.lanes() <= 1 || batch.len() <= 1 {
            self.denoise_into(model, batch, out)?;
            return Ok(None);
        }
        self.stage_batch(batch, out)?;
        self.conds.clear();
        for i in 0..batch.len() {
            let cond = Self::cond_of(&self.gmm, batch.token_row(i))?;
            self.conds.push(cond);
        }
        while self.lane_scratch.len() < exec.lanes() {
            let mut scratch = GmmScratch::default();
            // warmed so a lane's first mixture row never allocates
            scratch.warm(self.gmm.components());
            self.lane_scratch.push(scratch);
        }
        let gmm = &self.gmm;
        let conds = &self.conds;
        let rows = RowShards::new(out.data_mut(), gmm.dim);
        let scratches = SliceShards::new(&mut self.lane_scratch);
        let stats = exec.run(batch.len(), |lane, i| {
            // Safety: the pool claims each row index exactly once, and
            // `lane` is distinct per concurrently-running invocation.
            let row = unsafe { rows.row(i) };
            let scratch = unsafe { scratches.slot(lane) };
            gmm.eps_into(batch.x_row(i), batch.t(i) as f64, conds[i], row, scratch);
        });
        Ok(Some(stats))
    }

    fn models(&self) -> Vec<String> {
        vec!["gmm".to_owned()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gmm_backend_routes_condition_tokens() {
        let mut be = GmmBackend::new(Gmm::axes(4, 2, 2.0, 0.1));
        let x = vec![0.5f32; 4];
        let mk = |tok: i32| EvalInput {
            x: x.clone(),
            t: 0.5,
            tokens: vec![tok, 0, 0, 0],
        };
        let out = be.denoise("gmm", &[mk(0), mk(1), mk(2)]).unwrap();
        assert_eq!(out.len(), 3);
        // conditional scores for different components differ; both differ
        // from the unconditional mixture score.
        assert_ne!(out[1], out[2]);
        assert_ne!(out[0], out[1]);
        assert_eq!(be.calls, 1);
        assert_eq!(be.items_executed, 3);
    }

    #[test]
    fn gmm_backend_rejects_oversized_batch() {
        let mut be =
            GmmBackend::new(Gmm::axes(4, 2, 2.0, 0.1)).with_buckets(vec![1, 2]);
        let items: Vec<EvalInput> = (0..3)
            .map(|_| EvalInput {
                x: vec![0.0; 4],
                t: 0.5,
                tokens: vec![0; 4],
            })
            .collect();
        assert!(be.denoise("gmm", &items).is_err());
    }

    #[test]
    fn gmm_backend_rejects_empty_tokens_with_an_error() {
        let mut be = GmmBackend::new(Gmm::axes(4, 2, 2.0, 0.1));
        let item = EvalInput {
            x: vec![0.0; 4],
            t: 0.5,
            tokens: Vec::new(),
        };
        let err = be.denoise("gmm", &[item]).unwrap_err();
        assert!(err.to_string().contains("empty token row"), "{err}");
    }

    #[test]
    fn gmm_backend_rejects_out_of_range_component() {
        let mut be = GmmBackend::new(Gmm::axes(4, 2, 2.0, 0.1));
        let mk = |tok: i32| EvalInput {
            x: vec![0.0; 4],
            t: 0.5,
            tokens: vec![tok, 0, 0, 0],
        };
        let err = be.denoise("gmm", &[mk(3)]).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        assert!(be.denoise("gmm", &[mk(-1)]).is_err());
    }

    #[test]
    fn packed_and_per_item_paths_agree_bitwise() {
        let gmm = Gmm::axes(6, 3, 2.5, 0.1);
        let mut be = GmmBackend::new(gmm.clone());
        let items: Vec<EvalInput> = (0..3)
            .map(|i| EvalInput {
                x: (0..6).map(|j| (i * 6 + j) as f32 * 0.1 - 0.7).collect(),
                t: 0.4 + 0.1 * i as f32,
                tokens: vec![i as i32, 0, 0, 0],
            })
            .collect();
        let via_compat = be.denoise("gmm", &items).unwrap();
        // direct packed path
        let mut batch = BatchBuf::new(6, 4);
        for it in &items {
            let (x, toks) = batch.push_row(it.t);
            x.copy_from_slice(&it.x);
            toks.copy_from_slice(&it.tokens);
        }
        let mut out = BatchOut::default();
        be.denoise_into("gmm", &batch, &mut out).unwrap();
        for (i, row) in via_compat.iter().enumerate() {
            assert_eq!(&row[..], out.row(i), "row {i}");
        }
        // and both agree with the allocating oracle call
        for (i, it) in items.iter().enumerate() {
            let cond = if it.tokens[0] == 0 {
                None
            } else {
                Some((it.tokens[0] - 1) as usize)
            };
            assert_eq!(via_compat[i], gmm.eps(&it.x, it.t as f64, cond));
        }
    }

    #[test]
    fn sharded_execution_matches_serial_bitwise() {
        let gmm = Gmm::axes(6, 3, 2.5, 0.1);
        let mut batch = BatchBuf::new(6, 4);
        for i in 0..12 {
            let (x, toks) = batch.push_row(0.15 + 0.06 * i as f32);
            for (j, v) in x.iter_mut().enumerate() {
                *v = ((i * 6 + j) as f32).sin();
            }
            toks[0] = (i % 4) as i32; // mixes unconditional and all components
        }
        let mut serial_out = BatchOut::default();
        GmmBackend::new(gmm.clone())
            .denoise_into("gmm", &batch, &mut serial_out)
            .unwrap();
        for lanes in [1usize, 2, 4, 8] {
            let pool = crate::exec::ExecPool::new(lanes);
            let mut be = GmmBackend::new(gmm.clone());
            let mut out = BatchOut::default();
            be.denoise_into_par("gmm", &batch, &mut out, &pool).unwrap();
            assert_eq!(out.data(), serial_out.data(), "lanes {lanes}");
            assert_eq!((be.calls, be.items_executed), (1, 12), "lanes {lanes}");
        }
        // malformed rows error before any sharded work, like the serial path
        let mut bad = BatchBuf::new(6, 4);
        for tok in [1, 99] {
            let (_, toks) = bad.push_row(0.5);
            toks[0] = tok;
        }
        let pool = crate::exec::ExecPool::new(4);
        let mut be = GmmBackend::new(gmm);
        let mut out = BatchOut::default();
        let err = be.denoise_into_par("gmm", &bad, &mut out, &pool).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
    }

    #[test]
    fn batch_buf_reset_keeps_capacity_and_geometry() {
        let mut b = BatchBuf::new(4, 2);
        for i in 0..3 {
            let (x, toks) = b.push_row(i as f32);
            x.fill(i as f32);
            toks.fill(i);
        }
        assert_eq!(b.len(), 3);
        assert_eq!(b.x_row(1), &[1.0; 4]);
        assert_eq!(b.token_row(2), &[2, 2]);
        assert_eq!(b.t(0), 0.0);
        let cap = b.xs.capacity();
        b.reset(4, 2);
        assert!(b.is_empty());
        assert_eq!(b.xs.capacity(), cap, "reset must keep capacity");
        let (x, _) = b.push_row(9.0);
        assert_eq!(x, &[0.0; 4], "fresh rows are zeroed");
    }

    #[test]
    fn batch_out_rows_are_contiguous() {
        let mut o = BatchOut::default();
        o.reset(3, 2);
        o.row_mut(0).copy_from_slice(&[1.0, 2.0, 3.0]);
        o.row_mut(1).copy_from_slice(&[4.0, 5.0, 6.0]);
        assert_eq!(o.data(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(o.row(1), &[4.0, 5.0, 6.0]);
        o.reset(2, 1);
        assert_eq!(o.data(), &[0.0, 0.0], "reset zeroes the active rows");
    }
}
