//! `agd` — the Adaptive Guidance serving CLI (L3 leader entrypoint).
//!
//! Subcommands:
//!   info                         artifact + model inventory
//!   generate [--prompt ..]       generate images under a policy, write PPMs
//!   serve [--addr ..]            TCP line-protocol server
//!   replay [--trace ..]          replay a captured trace against a server
//!   profile [--spans ..]         render a drained spans capture (§Observability)
//!   search [--iters ..]          run the NAS policy search (§4)
//!   fit-ols [--train ..]         collect trajectories + fit LINEARAG OLS
//!
//! All subcommands load artifacts from `--artifacts` (default `artifacts/`).

use std::path::PathBuf;

use anyhow::{anyhow, Result};

use adaptive_guidance::backend::GmmBackend;
use adaptive_guidance::chaos;
use adaptive_guidance::coordinator::engine::Engine;
use adaptive_guidance::coordinator::policy::{cfg as cfg_policy, PolicyRef};
use adaptive_guidance::coordinator::request::Request;
use adaptive_guidance::coordinator::spec::{PolicyRegistry, PolicySpec};
use adaptive_guidance::fleet::Placement;
use adaptive_guidance::ols;
use adaptive_guidance::prompts::{self, Prompt};
use adaptive_guidance::runtime::PjrtBackend;
use adaptive_guidance::sched::{Admission, SchedulerKind};
use adaptive_guidance::search;
use adaptive_guidance::server::{serve_with_registry, NetMode, ServerConfig};
use adaptive_guidance::sim::gmm::Gmm;
use adaptive_guidance::util::cli::Args;
use adaptive_guidance::util::json;
use adaptive_guidance::util::ppm;
use adaptive_guidance::util::rng::Rng;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let result = match cmd {
        "info" => cmd_info(&args),
        "generate" => cmd_generate(&args),
        "serve" => cmd_serve(&args),
        "replay" => cmd_replay(&args),
        "profile" => cmd_profile(&args),
        "search" => cmd_search(&args),
        "fit-ols" => cmd_fit_ols(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    let names = PolicyRegistry::builtin().names().join("|");
    eprintln!(
        "agd — Adaptive Guidance diffusion serving\n\n\
         USAGE: agd <info|generate|serve|replay|profile|search|fit-ols> [options]\n\n\
         common options:\n\
           --artifacts DIR     artifacts directory (default: artifacts)\n\
           --model NAME        dit_s | dit_b (default: dit_b)\n\n\
         policies (--policy NAME or inline JSON {{\"kind\": ..}}):\n\
           {names}\n\
           parameters: --guidance F --gamma-bar F --cfg-steps N --period N\n\
           --coeffs FILE --choices LIST --s-text F --s-img F --full-prefix N\n\
           --s-max F --s-min F --gamma-lo F --gamma-hi F\n\n\
         generate: --prompt TEXT --negative TEXT --policy P\n\
           --steps N --seed N --n N --out DIR\n\
           --workers N         engine worker lanes (0 = all cores)\n\
         serve:    --addr HOST:PORT\n\
           --shards N           engine replicas, one thread/backend each (default 1)\n\
           --placement least-loaded|round-robin|client-hash (default least-loaded)\n\
           --scheduler fifo|cost-aware|deadline|fair-share (default fifo)\n\
           --max-queued-nfes N  fleet-wide queue_full budget in queued evals (0 = off)\n\
           --max-in-flight N    fleet-wide cap on concurrent requests (0 = off)\n\
           --shard-max-queued-nfes N  per-shard queued-eval budget (0 = off)\n\
           --shard-max-in-flight N    per-shard concurrent-request cap (0 = off)\n\
           --max-in-flight-per-client N  per-client_id cap, shard-side (0 = off)\n\
           --shed-infeasible    refuse requests whose deadline_ms cannot cover\n\
                                the shard backlog at the observed service rate\n\
           --workers N          worker lanes per shard (0 = cores/shards, default)\n\
           --policy-file FILE   register policy aliases from JSON at startup\n\
           --coeffs-dir DIR     server-side dir for linear-ag \"coeffs_file\"\n\
           --backend pjrt|gmm   gmm = artifact-free analytic backend (default pjrt)\n\
           --max-line-bytes N   refuse+close frames past N bytes (default 1 MiB)\n\
           --read-timeout-ms N  idle/slowloris connection cutoff (default 60000, 0 = off)\n\
           --trace-out FILE     append one JSONL record per served request\n\
           --spans-out FILE     continuously ship lifecycle/guidance spans to a\n\
                                JSONL file (500ms cadence; mirrors --trace-out)\n\
           --net reactor|threads  connection front end: poll-based reactor with\n\
                                pipelined ids, progress streaming and cancel\n\
                                (default), or thread-per-connection baseline\n\
           --fault-spec SPEC    arm backend fault injection at startup, e.g.\n\
                                error-every=50,stall-at=120:200 (docs/ROBUSTNESS.md)\n\
           --max-batch-retries N  per-batch transient-fault retry budget (default 0)\n\
           --shard-respawn      supervisor respawns dead shards (capped backoff)\n\
           --checkpoint-steps N checkpoint each request every N completed steps so\n\
                                a dying shard's started work resumes mid-flight on\n\
                                survivors, byte-identical (default 0 = off)\n\
         replay:   --trace FILE (required; a --trace-out capture)\n\
           --addr HOST:PORT --speed X --connections N --timeout-ms N\n\
           --max-in-flight N    closed-loop: ignore the captured schedule,\n\
                                keep N requests in flight per connection\n\
                                (0 = open-loop at the captured rate)\n\
           --pipeline DEPTH     tag requests with wire ids and keep DEPTH\n\
                                pipelined per connection (reactor protocol;\n\
                                0 = one-at-a-time, the historical framing)\n\
           --out FILE           wire-latency report (default BENCH_replay.json)\n\
         profile:  --spans FILE (required; a {{\"cmd\": \"spans\"}} reply, JSON or JSONL)\n\
           --out FILE           Chrome trace JSON for chrome://tracing or\n\
                                Perfetto (default PROFILE_trace.json)\n\
           prints per-stage p50/p95/p99 and the per-policy NFE-savings\n\
           ledger; see docs/OBSERVABILITY.md\n\
         search:   --iters N --lr F --seed N --out FILE\n\
         fit-ols:  --train N --test N --steps N --out FILE"
    );
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("artifacts", "artifacts"))
}

fn backend(args: &Args) -> Result<PjrtBackend> {
    PjrtBackend::load(&artifacts_dir(args))
}

/// Build the requested policy through the PolicySpec wire format — every
/// registered policy (built-ins and plugins) is reachable from the CLI.
fn policy_from_args(args: &Args) -> Result<PolicyRef> {
    let spec = PolicySpec::from_cli(args)?;
    Ok(PolicyRegistry::builtin().build(&spec)?)
}

fn cmd_info(args: &Args) -> Result<()> {
    let be = backend(args)?;
    let m = &be.manifest;
    println!("artifacts: {}", m.root.display());
    println!(
        "latent: {}x{}x{} (flat {})  buckets {:?}",
        m.img, m.img, m.channels, m.flat_dim, m.buckets
    );
    println!(
        "defaults: guidance {} steps {}",
        m.default_guidance, m.default_steps
    );
    for (name, meta) in &m.models {
        println!(
            "model {name}: {} params, in_channels {}, buckets {:?}",
            meta.params, meta.in_channels, meta.buckets
        );
    }
    println!(
        "search graph: {} (T={} options={:?})",
        m.search.artifact.as_deref().unwrap_or("<missing>"),
        m.search.steps,
        m.search.options
    );
    println!("prompt space: {} prompts", Prompt::space_size());
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let be = backend(args)?;
    let model = args.get_or("model", "dit_b").to_owned();
    let img = be.manifest.img;
    let steps = args.usize("steps", be.manifest.default_steps);
    let n = args.usize("n", 4);
    let seed = args.u64("seed", 0);
    let policy = policy_from_args(args)?;
    policy
        .validate(steps)
        .map_err(|e| anyhow!("policy `{}`: {e}", policy.name()))?;
    let out_dir = PathBuf::from(args.get_or("out", "out"));
    std::fs::create_dir_all(&out_dir)?;

    let mut engine = Engine::new(be)?;
    engine.set_workers(match args.usize("workers", 0) {
        0 => adaptive_guidance::exec::default_workers(),
        n => n,
    });
    let prompt_list: Vec<Prompt> = match args.get("prompt") {
        Some(text) => vec![Prompt::parse(text).ok_or_else(|| anyhow!("bad prompt"))?],
        None => prompts::eval_set(n, seed),
    };
    let mut reqs = Vec::new();
    for i in 0..n {
        let p = prompt_list[i % prompt_list.len()];
        let mut r = Request::new(i as u64, &model, p.tokens(), seed + i as u64,
                                 steps, policy.clone());
        if let Some(neg) = args.get("negative") {
            let np = Prompt::parse(neg).unwrap();
            r.neg_tokens = Some(prompts::negative_tokens(1, np.color as i32 + 1));
        }
        reqs.push((p, r));
    }
    let started = std::time::Instant::now();
    let completions = engine.run(reqs.iter().map(|(_, r)| r.clone()).collect())?;
    let elapsed = started.elapsed().as_secs_f64();
    let mut total_nfes = 0;
    for ((p, _), c) in reqs.iter().zip(&completions) {
        total_nfes += c.nfes;
        let up = ppm::upscale(&c.image, img, img, 8);
        let path = out_dir.join(format!("sample_{}.ppm", c.id));
        ppm::write_ppm(&path, &up, img * 8, img * 8)?;
        println!(
            "#{} \"{}\" nfes={} truncated_at={:?} -> {}",
            c.id,
            p.text(),
            c.nfes,
            c.truncated_at,
            path.display()
        );
    }
    println!(
        "policy {}: {} images, {} NFEs total ({:.1} avg), {:.2}s, occupancy {:.1}",
        policy.name(),
        completions.len(),
        total_nfes,
        total_nfes as f64 / completions.len() as f64,
        elapsed,
        engine.mean_occupancy()
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    // --backend gmm serves the analytic mixture backend — artifact-free,
    // which is what the chaos/replay harness (`scripts/chaos.sh`) runs
    // against on machines without the compiled DiT artifacts
    let backend_kind = args
        .choice("backend", "pjrt", &["pjrt", "gmm"])
        .map_err(|e| anyhow!(e))?
        .to_owned();
    let default_model = if backend_kind == "gmm" { "gmm" } else { "dit_b" };
    let model = args.get_or("model", default_model).to_owned();
    let dir = artifacts_dir(args);
    let scheduler = SchedulerKind::parse(args.get_or("scheduler", "fifo"))
        .map_err(|e| anyhow!("--scheduler: {e}"))?;
    // the fleet topology: N engine replicas behind a load-aware router
    let placement = Placement::parse(
        args.choice(
            "placement",
            "least-loaded",
            &["least-loaded", "round-robin", "client-hash"],
        )
        .map_err(|e| anyhow!(e))?,
    )
    .expect("choice() validated the placement name");
    // 0 = unlimited, matching the historical unbounded queue
    let nonzero = |n: usize| if n == 0 { None } else { Some(n) };
    let admission = Admission {
        max_in_flight: nonzero(args.usize("max-in-flight", 0)),
        max_queued_nfes: nonzero(args.usize("max-queued-nfes", 0)),
        max_in_flight_per_client: nonzero(args.usize("max-in-flight-per-client", 0)),
    };
    let shard_admission = Admission {
        max_in_flight: nonzero(args.usize("shard-max-in-flight", 0)),
        max_queued_nfes: nonzero(args.usize("shard-max-queued-nfes", 0)),
        max_in_flight_per_client: None,
    };
    let cfg = ServerConfig {
        addr: args.get_or("addr", "127.0.0.1:7458").to_owned(),
        model: model.clone(),
        default_steps: args.usize("steps", 20),
        default_guidance: args.f64("guidance", 7.5),
        default_gamma_bar: args.f64("gamma-bar", 0.9988),
        scheduler,
        admission,
        shard_admission,
        shards: args.usize("shards", 1).max(1),
        placement,
        shed_infeasible: args.flag("shed-infeasible"),
        // 0 = available parallelism split across shards, resolved by the fleet
        workers: args.usize("workers", 0),
        // §Robustness: wire hardening + trace capture
        max_line_bytes: args.usize("max-line-bytes", 1 << 20),
        read_timeout_ms: args.u64("read-timeout-ms", 60_000),
        trace_out: args.get("trace-out").map(str::to_owned),
        // §Scale: front-end selection — the poll reactor (default) or
        // the thread-per-connection baseline for A/B comparison
        net: NetMode::parse(args.choice("net", "reactor", &["reactor", "threads"]).map_err(|e| anyhow!(e))?)
            .expect("choice() validated the net mode"),
        spans_out: args.get("spans-out").map(str::to_owned),
        // §Robustness: fault injection + retry + supervision
        fault_spec: args.get("fault-spec").map(str::to_owned),
        max_batch_retries: args.usize("max-batch-retries", 0),
        shard_respawn: args.flag("shard-respawn"),
        checkpoint_steps: args.usize("checkpoint-steps", 0),
    };
    // named policy presets extend the registry before the first request —
    // a bad file is a startup error, not a first-request surprise
    let mut registry = PolicyRegistry::builtin();
    if let Some(dir) = args.get("coeffs-dir") {
        registry.set_coeffs_dir(dir);
    }
    if let Some(path) = args.get("policy-file") {
        let n = registry
            .load_alias_file(path)
            .map_err(|e| anyhow!("--policy-file: {e}"))?;
        eprintln!("loaded {n} policy aliases from {path}");
    }
    let registry = std::sync::Arc::new(registry);
    if backend_kind == "gmm" {
        return serve_with_registry(
            move || Ok(GmmBackend::new(Gmm::axes(8, 4, 3.0, 0.05))),
            cfg,
            registry,
        );
    }
    // the PJRT client is thread-affine: the factory is called inside each
    // shard's engine thread (once per `--shards` replica)
    serve_with_registry(
        move || {
            let mut be = PjrtBackend::load(&dir)?;
            be.warmup(&model)?;
            Ok(be)
        },
        cfg,
        registry,
    )
}

/// `agd replay`: fire a captured trace (`--trace-out` JSONL) back at a
/// live server, open-loop at `--speed`× across `--connections` sockets,
/// digest-checking every completion against the capture and writing the
/// wire-latency report to `--out` (default `BENCH_replay.json`).
fn cmd_replay(args: &Args) -> Result<()> {
    let trace_path = args
        .get("trace")
        .ok_or_else(|| anyhow!("replay needs --trace FILE (a --trace-out capture)"))?;
    let records = chaos::read_trace(trace_path)?;
    let cfg = chaos::ReplayConfig {
        addr: args.get_or("addr", "127.0.0.1:7458").to_owned(),
        speed: args.f64("speed", 1.0),
        connections: args.usize("connections", 4).max(1),
        timeout_ms: args.u64("timeout-ms", 30_000),
        // 0 = open-loop (captured schedule); N = closed-loop throughput
        // measurement at N in-flight per connection (§Observability)
        max_in_flight: args.usize("max-in-flight", 0),
        // 0 = historical one-at-a-time framing; N = wire-id pipelining
        // at depth N per connection (reactor protocol, §Scale)
        pipeline: args.usize("pipeline", 0),
    };
    let mode = if cfg.pipeline > 0 {
        format!("pipelined, depth {}/conn", cfg.pipeline)
    } else if cfg.max_in_flight > 0 {
        format!("closed-loop, {} in flight/conn", cfg.max_in_flight)
    } else {
        format!("open-loop, speed {}x", cfg.speed)
    };
    eprintln!(
        "replaying {} records from {trace_path} against {} ({mode}, {} connections)",
        records.len(),
        cfg.addr,
        cfg.connections
    );
    let outcome = chaos::replay(&records, &cfg)?;
    let shed: Vec<String> = outcome
        .shed
        .iter()
        .map(|(code, n)| format!("{code}={n}"))
        .collect();
    println!(
        "sent {} completed {} shed {} [{}] transport_errors {} wall {:.0}ms \
         achieved {:.1} req/s",
        outcome.sent,
        outcome.completed,
        outcome.shed_total(),
        shed.join(","),
        outcome.transport_errors,
        outcome.wall_ms,
        outcome.completed as f64 / (outcome.wall_ms / 1e3).max(1e-9)
    );
    println!(
        "digests: {} checked, {} mismatched",
        outcome.digest_checked, outcome.digest_mismatches
    );
    // §Robustness: scrape the fleet's survival counters post-run — how
    // many batches were retried, jobs salvaged, shards died/respawned
    // while the replay was being served. A failed scrape degrades to a
    // report without the survival section (the server may already be
    // gone); it never fails the replay itself.
    let survival = match chaos::replay::fetch_survival(&cfg.addr, cfg.timeout_ms) {
        Ok(s) => {
            println!(
                "survival: {} batch retries, {} jobs salvaged, {} jobs resumed, \
                 {} shard deaths, {} respawns",
                s.batch_retries, s.jobs_salvaged, s.jobs_resumed, s.shards_died,
                s.shards_respawned
            );
            Some(s)
        }
        Err(e) => {
            eprintln!("stats scrape failed (report omits survival counters): {e:#}");
            None
        }
    };
    let out = args.get_or("out", "BENCH_replay.json");
    chaos::replay::write_report(out, &outcome, &cfg, survival.as_ref())?;
    // a digest divergence means the server did not serve what it served
    // at capture time — fail loudly so CI catches it
    anyhow::ensure!(
        outcome.digest_mismatches == 0,
        "{} of {} digest-checked completions diverged from the capture",
        outcome.digest_mismatches,
        outcome.digest_checked
    );
    Ok(())
}

/// `agd profile`: render a drained spans capture (§Observability) — the
/// saved reply of `{"cmd": "spans"}`, or any JSONL of span/guidance
/// events — into Chrome trace-event JSON (`--out`, loadable at
/// chrome://tracing or <https://ui.perfetto.dev>) plus two stdout tables:
/// per-stage latency percentiles and the per-policy realized-NFE-savings
/// ledger. Walkthrough in `docs/OBSERVABILITY.md`.
fn cmd_profile(args: &Args) -> Result<()> {
    use adaptive_guidance::trace::profile;

    let spans_path = args.get("spans").ok_or_else(|| {
        anyhow!("profile needs --spans FILE (a saved {{\"cmd\": \"spans\"}} reply)")
    })?;
    let text = std::fs::read_to_string(spans_path)
        .map_err(|e| anyhow!("reading {spans_path}: {e}"))?;
    let events = adaptive_guidance::trace::parse_capture(&text)?;
    anyhow::ensure!(!events.is_empty(), "{spans_path} holds no trace events");
    let spans = events
        .iter()
        .filter(|e| e.get("type").and_then(json::Value::as_str) == Some("span"))
        .count();
    eprintln!(
        "{}: {} events ({} spans, {} guidance)",
        spans_path,
        events.len(),
        spans,
        events.len() - spans
    );

    let out = args.get_or("out", "PROFILE_trace.json");
    std::fs::write(out, json::to_string(&profile::chrome_trace(&events)))
        .map_err(|e| anyhow!("writing {out}: {e}"))?;
    eprintln!("chrome trace written to {out} (open in chrome://tracing or Perfetto)");

    let summaries = profile::stage_summaries(&events);
    if summaries.is_empty() {
        eprintln!("no lifecycle spans in the capture (no \"trace\": true requests?)");
    } else {
        adaptive_guidance::perfstat::print_summaries(&summaries);
    }
    let ledger = profile::policy_ledger(&events);
    if !ledger.is_empty() {
        println!("realized NFE savings by policy (final guidance events):");
        adaptive_guidance::eval::harness::print_table(
            &["policy", "requests", "nfes", "max_nfes", "saved", "truncated"],
            &ledger.iter().map(profile::LedgerRow::row).collect::<Vec<_>>(),
        );
    }
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    let mut be = backend(args)?;
    let meta = be.manifest.search.clone();
    let latent_len = be.manifest.flat_dim;
    let cfg = search::SearchConfig {
        steps: meta.steps,
        options: meta.options.len(),
        batch: meta.batch,
        latent_len,
        iters: args.usize("iters", 60),
        lr: args.f32("lr", 0.02),
        seed: args.u64("seed", 0),
    };
    eprintln!(
        "searching: T={} options={} iters={} (target cost {})",
        cfg.steps, cfg.options, cfg.iters, meta.cost_target
    );
    let mut grad = |a: &[f32], g: &[f32], x: &[f32], t: &[i32]| be.run_search_grad(a, g, x, t);
    let res = search::run_search(&mut grad, &cfg, |rng: &mut Rng| {
        Prompt::nth(rng.below(Prompt::space_size())).tokens()
    })?;
    println!("step  {:>9} {:>9} {:>9} {:>9} {:>9}", "uncond", "cond", "cfg/2", "cfg", "cfg*2");
    for (t, row) in res.scores().iter().enumerate() {
        println!(
            "{t:>4}  {:>9.3} {:>9.3} {:>9.3} {:>9.3} {:>9.3}",
            row[0], row[1], row[2], row[3], row[4]
        );
    }
    println!(
        "final loss {:.5} mse {:.5} soft-NFE {:.2}",
        res.trace.loss.last().unwrap(),
        res.trace.mse.last().unwrap(),
        res.trace.soft_nfe.last().unwrap()
    );
    if let Some(path) = args.get("out") {
        let v = json::obj(vec![
            (
                "alpha",
                json::arr(res.alpha.iter().map(|&a| json::num(a as f64)).collect()),
            ),
            ("steps", json::num(res.steps as f64)),
            ("options", json::num(res.options as f64)),
        ]);
        std::fs::write(path, json::to_string(&v))?;
        eprintln!("alpha written to {path}");
    }
    Ok(())
}

fn cmd_fit_ols(args: &Args) -> Result<()> {
    let be = backend(args)?;
    let model = args.get_or("model", "dit_b").to_owned();
    let steps = args.usize("steps", 20);
    let n_train = args.usize("train", 200);
    let n_test = args.usize("test", 100);
    let s = args.f32("guidance", 7.5);
    let seed = args.u64("seed", 0);
    let out = args.get_or("out", "artifacts/ols_coeffs.json").to_owned();

    let mut engine = Engine::new(be)?;
    let trajs = collect_trajectories(&mut engine, &model, n_train + n_test, steps, s, seed)?;
    let (train, test) = trajs.split_at(n_train);
    eprintln!("fitting OLS on {} trajectories ({} held out)", train.len(), test.len());
    let coeffs = ols::fit(train, 1e-6);
    let train_mse = ols::eval_mse(&coeffs, train);
    let test_mse = ols::eval_mse(&coeffs, test);
    println!("step  {:>12} {:>12}", "train MSE", "test MSE");
    for t in 0..steps {
        println!("{t:>4}  {:>12.6} {:>12.6}", train_mse[t], test_mse[t]);
    }
    std::fs::write(&out, json::to_string(&coeffs.to_json()))?;
    eprintln!("coefficients written to {out}");
    Ok(())
}

/// Generate `n` CFG trajectories with score recording (shared by fit-ols and
/// the LINEARAG example).
pub fn collect_trajectories(
    engine: &mut Engine<PjrtBackend>,
    model: &str,
    n: usize,
    steps: usize,
    s: f32,
    seed: u64,
) -> Result<Vec<ols::ScoreTrajectory>> {
    let ps = prompts::eval_set(n, seed);
    let reqs: Vec<Request> = ps
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let mut r = Request::new(i as u64, model, p.tokens(), seed + i as u64,
                                     steps, cfg_policy(s));
            r.record_trajectory = true;
            r
        })
        .collect();
    let completions = engine.run(reqs)?;
    Ok(completions
        .into_iter()
        .map(|c| c.trajectory.expect("trajectory recorded"))
        .collect())
}

