//! Rust-driven differentiable NAS search (paper §4).
//!
//! The gradient of the Eq. 6 objective w.r.t. the architecture scores α is
//! computed by one AOT'd HLO module (`search_grad.hlo.txt`, lowered from
//! `python/compile/search_graph.py`); this module owns the optimization loop
//! around it — the Lion optimizer the paper uses, Gumbel sampling, data
//! sampling, and policy extraction from the trained α.

pub mod lion;

use crate::coordinator::policy::{Searched, StepChoice};
use crate::util::rng::Rng;

pub use lion::Lion;

/// One search-gradient evaluation: `(alpha, gumbel, x_t, tokens)` →
/// `(loss, grad_alpha, replication_mse, soft_nfe)`. Implemented by
/// `PjrtBackend::run_search_grad` in production and by closures in tests.
pub trait SearchGrad {
    fn eval(
        &mut self,
        alpha: &[f32],
        gumbel: &[f32],
        x_t: &[f32],
        tokens: &[i32],
    ) -> anyhow::Result<(f32, Vec<f32>, f32, f32)>;
}

impl<F> SearchGrad for F
where
    F: FnMut(&[f32], &[f32], &[f32], &[i32]) -> anyhow::Result<(f32, Vec<f32>, f32, f32)>,
{
    fn eval(
        &mut self,
        alpha: &[f32],
        gumbel: &[f32],
        x_t: &[f32],
        tokens: &[i32],
    ) -> anyhow::Result<(f32, Vec<f32>, f32, f32)> {
        self(alpha, gumbel, x_t, tokens)
    }
}

/// Search hyper-parameters (§4.1: Lion, 5 epochs over noise-image pairs).
#[derive(Debug, Clone)]
pub struct SearchConfig {
    pub steps: usize,
    pub options: usize,
    pub batch: usize,
    pub latent_len: usize,
    pub iters: usize,
    pub lr: f32,
    pub seed: u64,
}

/// Iteration record for reporting (Fig. 3 aggregates these).
#[derive(Debug, Clone)]
pub struct SearchTrace {
    pub loss: Vec<f32>,
    pub mse: Vec<f32>,
    pub soft_nfe: Vec<f32>,
}

/// Result of one search run.
#[derive(Debug, Clone)]
pub struct SearchResult {
    /// final architecture scores, row-major (steps, options)
    pub alpha: Vec<f32>,
    pub steps: usize,
    pub options: usize,
    pub trace: SearchTrace,
}

impl SearchResult {
    /// softmax(α_t) per step — the multinomial the paper samples policies from.
    pub fn scores(&self) -> Vec<Vec<f64>> {
        (0..self.steps)
            .map(|t| {
                let row = &self.alpha[t * self.options..(t + 1) * self.options];
                let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let exps: Vec<f64> = row.iter().map(|&v| ((v - max) as f64).exp()).collect();
                let z: f64 = exps.iter().sum();
                exps.into_iter().map(|e| e / z).collect()
            })
            .collect()
    }

    /// Extract the argmax (discrete) policy. Option order is the search
    /// space of §4.1: [uncond, cond, cfg(s/2), cfg(s), cfg(2s)]. Returns
    /// the concrete [`Searched`] policy so callers can inspect the choices
    /// (use `.into_ref()` to submit it to the engine).
    pub fn extract_policy(&self, s_base: f32) -> Searched {
        let choices = self
            .scores()
            .iter()
            .map(|row| {
                let best = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                match best {
                    0 => StepChoice::Uncond,
                    1 => StepChoice::Cond,
                    2 => StepChoice::Cfg { s: 0.5 * s_base },
                    3 => StepChoice::Cfg { s: s_base },
                    _ => StepChoice::Cfg { s: 2.0 * s_base },
                }
            })
            .collect();
        Searched { choices }
    }
}

/// Run the DARTS-style search: α initialized i.i.d. uniform (§4), Lion
/// updates on the AOT'd gradient, fresh (x_T, prompt, gumbel) each iteration.
///
/// `sample_tokens` supplies condition tokens for a batch (e.g. random
/// prompts from the OUI-substitute set).
pub fn run_search<G: SearchGrad>(
    grad: &mut G,
    cfg: &SearchConfig,
    mut sample_tokens: impl FnMut(&mut Rng) -> Vec<i32>,
) -> anyhow::Result<SearchResult> {
    let n = cfg.steps * cfg.options;
    let mut rng = Rng::new(cfg.seed);
    let mut alpha: Vec<f32> = (0..n).map(|_| rng.range(-0.01, 0.01) as f32).collect();
    let mut opt = Lion::new(n, cfg.lr, 0.9, 0.99);
    let mut trace = SearchTrace {
        loss: Vec::new(),
        mse: Vec::new(),
        soft_nfe: Vec::new(),
    };
    for _ in 0..cfg.iters {
        let gumbel: Vec<f32> = (0..n).map(|_| rng.gumbel() as f32).collect();
        let x_t: Vec<f32> = rng.normal_vec(cfg.batch * cfg.latent_len);
        let mut tokens = Vec::with_capacity(cfg.batch * 4);
        for _ in 0..cfg.batch {
            tokens.extend(sample_tokens(&mut rng));
        }
        let (loss, g, mse, nfe) = grad.eval(&alpha, &gumbel, &x_t, &tokens)?;
        anyhow::ensure!(g.len() == n, "gradient length mismatch");
        opt.step(&mut alpha, &g);
        trace.loss.push(loss);
        trace.mse.push(mse);
        trace.soft_nfe.push(nfe);
    }
    Ok(SearchResult {
        alpha,
        steps: cfg.steps,
        options: cfg.options,
        trace,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic objective: per-step target option; gradient of
    /// cross-entropy-like loss pushes alpha toward the target. Verifies the
    /// loop + Lion converge and the extracted policy matches.
    #[test]
    fn search_loop_converges_on_synthetic_objective() {
        let steps = 6;
        let options = 5;
        let targets = [3usize, 3, 3, 1, 1, 1]; // cfg early, cond late (Fig. 3!)
        let mut grad_fn = |alpha: &[f32], _g: &[f32], _x: &[f32], _t: &[i32]| {
            let mut grad = vec![0.0f32; alpha.len()];
            let mut loss = 0.0f32;
            for s in 0..steps {
                let row = &alpha[s * options..(s + 1) * options];
                let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let exps: Vec<f32> = row.iter().map(|v| (v - max).exp()).collect();
                let z: f32 = exps.iter().sum();
                for o in 0..options {
                    let p = exps[o] / z;
                    let y = if o == targets[s] { 1.0 } else { 0.0 };
                    grad[s * options + o] = p - y;
                    if y > 0.0 {
                        loss -= p.max(1e-9).ln();
                    }
                }
            }
            Ok((loss, grad, loss, 30.0))
        };
        let cfg = SearchConfig {
            steps,
            options,
            batch: 2,
            latent_len: 8,
            iters: 300,
            lr: 0.05,
            seed: 0,
        };
        let res = run_search(&mut grad_fn, &cfg, |_rng| vec![1, 1, 1, 1]).unwrap();
        let scores = res.scores();
        for (s, row) in scores.iter().enumerate() {
            let best = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            assert_eq!(best, targets[s], "step {s}: {row:?}");
        }
        // loss decreased
        assert!(res.trace.loss.last().unwrap() < &res.trace.loss[0]);
        // extracted policy mirrors the targets
        let policy = res.extract_policy(7.5);
        assert_eq!(policy.choices[0], StepChoice::Cfg { s: 7.5 });
        assert_eq!(policy.choices[5], StepChoice::Cond);
    }

    #[test]
    fn scores_are_distributions() {
        let res = SearchResult {
            alpha: vec![0.5, -1.0, 2.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0],
            steps: 2,
            options: 5,
            trace: SearchTrace {
                loss: vec![],
                mse: vec![],
                soft_nfe: vec![],
            },
        };
        for row in res.scores() {
            let sum: f64 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-12);
            assert!(row.iter().all(|&p| p >= 0.0));
        }
    }
}
