//! Lion optimizer (Chen et al. 2023) — the optimizer the paper uses for the
//! NAS search (§4.1): sign-of-interpolated-momentum updates.
//!
//!   update = sign(β1 · m + (1 − β1) · g)
//!   θ     ← θ − lr · update
//!   m     ← β2 · m + (1 − β2) · g

#[derive(Debug, Clone)]
pub struct Lion {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    momentum: Vec<f32>,
}

impl Lion {
    pub fn new(n: usize, lr: f32, beta1: f32, beta2: f32) -> Lion {
        Lion {
            lr,
            beta1,
            beta2,
            momentum: vec![0.0; n],
        }
    }

    pub fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), self.momentum.len());
        assert_eq!(grads.len(), self.momentum.len());
        for i in 0..params.len() {
            let interp = self.beta1 * self.momentum[i] + (1.0 - self.beta1) * grads[i];
            params[i] -= self.lr * interp.signum() * (interp != 0.0) as u8 as f32;
            self.momentum[i] = self.beta2 * self.momentum[i] + (1.0 - self.beta2) * grads[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moves_against_gradient_sign() {
        let mut opt = Lion::new(3, 0.1, 0.9, 0.99);
        let mut p = vec![1.0f32, 1.0, 1.0];
        opt.step(&mut p, &[2.0, -0.3, 0.0]);
        assert!((p[0] - 0.9).abs() < 1e-6); // positive grad → step down by lr
        assert!((p[1] - 1.1).abs() < 1e-6); // negative grad → step up by lr
        assert!((p[2] - 1.0).abs() < 1e-6); // zero grad, zero momentum → no move
    }

    #[test]
    fn update_magnitude_is_always_lr() {
        let mut opt = Lion::new(1, 0.05, 0.9, 0.99);
        let mut p = vec![0.0f32];
        for g in [100.0f32, 0.001, -7.0] {
            let before = p[0];
            opt.step(&mut p, &[g]);
            assert!(((p[0] - before).abs() - 0.05).abs() < 1e-7);
        }
    }

    #[test]
    fn minimizes_quadratic() {
        let mut opt = Lion::new(2, 0.01, 0.9, 0.99);
        let mut p = vec![3.0f32, -2.0];
        for _ in 0..1000 {
            let g = vec![2.0 * p[0], 2.0 * p[1]];
            opt.step(&mut p, &g);
        }
        assert!(p[0].abs() < 0.05, "{p:?}");
        assert!(p[1].abs() < 0.05, "{p:?}");
    }

    #[test]
    fn momentum_smooths_oscillating_gradients() {
        // alternating gradients: with momentum, updates eventually follow
        // the mean direction (positive → params decrease).
        let mut opt = Lion::new(1, 0.01, 0.9, 0.99);
        let mut p = vec![0.0f32];
        for i in 0..200 {
            let g = if i % 2 == 0 { 3.0 } else { -1.0 }; // mean +1
            opt.step(&mut p, &[g]);
        }
        assert!(p[0] < 0.0, "{p:?}");
    }
}
