//! Small dense linear algebra: Cholesky solve for symmetric positive
//! definite systems (the OLS normal equations; K ≤ 41 for T = 20).

/// Solve `A x = b` for SPD `A` (row-major `n x n`). Returns `None` if the
/// factorization encounters a non-positive pivot (singular / not PD).
pub fn solve_spd(a: &[f64], b: &[f64], n: usize) -> Option<Vec<f64>> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n);
    // Cholesky: A = L L^T, lower-triangular L stored in place.
    let mut l = a.to_vec();
    for j in 0..n {
        let mut diag = l[j * n + j];
        for k in 0..j {
            diag -= l[j * n + k] * l[j * n + k];
        }
        if diag <= 0.0 || !diag.is_finite() {
            return None;
        }
        let dsqrt = diag.sqrt();
        l[j * n + j] = dsqrt;
        for i in j + 1..n {
            let mut v = l[i * n + j];
            for k in 0..j {
                v -= l[i * n + k] * l[j * n + k];
            }
            l[i * n + j] = v / dsqrt;
        }
    }
    // forward solve L y = b
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut v = b[i];
        for k in 0..i {
            v -= l[i * n + k] * y[k];
        }
        y[i] = v / l[i * n + i];
    }
    // back solve L^T x = y
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut v = y[i];
        for k in i + 1..n {
            v -= l[k * n + i] * x[k];
        }
        x[i] = v / l[i * n + i];
    }
    Some(x)
}

/// Matrix-vector product for row-major `n x n` (test helper + residual checks).
pub fn matvec(a: &[f64], x: &[f64], n: usize) -> Vec<f64> {
    (0..n)
        .map(|i| (0..n).map(|j| a[i * n + j] * x[j]).sum())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn solves_identity() {
        let n = 4;
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        let b = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(solve_spd(&a, &b, n).unwrap(), b);
    }

    #[test]
    fn solves_random_spd() {
        let mut rng = Rng::new(0);
        for n in [1usize, 3, 8, 20, 41] {
            // A = M M^T + eps I is SPD
            let m: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
            let mut a = vec![0.0; n * n];
            for i in 0..n {
                for j in 0..n {
                    let mut s = 0.0;
                    for k in 0..n {
                        s += m[i * n + k] * m[j * n + k];
                    }
                    a[i * n + j] = s + if i == j { 0.1 } else { 0.0 };
                }
            }
            let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = matvec(&a, &x_true, n);
            let x = solve_spd(&a, &b, n).unwrap();
            for (xs, xt) in x.iter().zip(&x_true) {
                assert!((xs - xt).abs() < 1e-8, "n={n}");
            }
        }
    }

    #[test]
    fn rejects_indefinite() {
        // A = [[1, 2], [2, 1]] has a negative eigenvalue.
        let a = vec![1.0, 2.0, 2.0, 1.0];
        assert!(solve_spd(&a, &[1.0, 1.0], 2).is_none());
    }
}
