//! LINEARAG's per-step Ordinary Least Squares (paper §5.1 / Appendix C).
//!
//! For each diffusion step t, learns *scalar* coefficients β so that the
//! unconditional score is predicted from the trajectory history (Eq. 8):
//!
//!   ε̂(x_t, ∅) = Σ_{i=T..t} β_i^c ε(x_i, c)  +  Σ_{i=T..t+1} β_i^∅ ε(x_i, ∅)
//!
//! One regression per step, fit over a set of recorded trajectories by
//! solving the normal equations with a Cholesky factorization (K ≤ 2T + 1
//! regressors, so the Gram matrix is tiny regardless of latent size).

pub mod linalg;

use crate::tensor::Tensor;

/// Recorded score history of one generation (conditional and unconditional
/// evaluations per step, step 0 = t=T).
#[derive(Debug, Clone)]
pub struct ScoreTrajectory {
    pub eps_c: Vec<Tensor>,
    pub eps_u: Vec<Tensor>,
}

impl ScoreTrajectory {
    pub fn steps(&self) -> usize {
        self.eps_c.len()
    }
}

/// Learned coefficients for every step: `beta_c[t]` has `t + 1` entries
/// (conditional scores at steps 0..=t), `beta_u[t]` has `t` entries
/// (unconditional scores at steps 0..t).
#[derive(Debug, Clone, PartialEq)]
pub struct OlsCoeffs {
    pub beta_c: Vec<Vec<f64>>,
    pub beta_u: Vec<Vec<f64>>,
}

impl OlsCoeffs {
    pub fn steps(&self) -> usize {
        self.beta_c.len()
    }

    /// The trivial estimator ε̂(x_t, ∅) = ε(x_t, c): a `beta_c` of all zeros
    /// except 1.0 on the current conditional score. Useful as a baseline
    /// (LINEARAG degenerates to conditional-only guidance under it) and as
    /// a fit-free stand-in for tests and wire-format examples.
    pub fn identity(steps: usize) -> OlsCoeffs {
        OlsCoeffs {
            beta_c: (0..steps)
                .map(|t| {
                    let mut b = vec![0.0; t + 1];
                    b[t] = 1.0;
                    b
                })
                .collect(),
            beta_u: (0..steps).map(|t| vec![0.0; t]).collect(),
        }
    }

    /// Predict ε̂(x_t, ∅) for step `t` given the history so far. `eps_u_hist`
    /// may contain earlier *estimates* when running autoregressively (the
    /// LINEARAG policy substitutes its own predictions).
    pub fn predict(&self, t: usize, eps_c_hist: &[Tensor], eps_u_hist: &[Tensor]) -> Tensor {
        assert!(t < self.steps());
        assert!(eps_c_hist.len() >= t + 1, "need cond history through step t");
        assert!(eps_u_hist.len() >= t, "need uncond history before step t");
        let dim = eps_c_hist[0].len();
        let mut out = Tensor::zeros(vec![dim]);
        for (i, b) in self.beta_c[t].iter().enumerate() {
            out.axpy(*b as f32, &eps_c_hist[i]);
        }
        for (i, b) in self.beta_u[t].iter().enumerate() {
            out.axpy(*b as f32, &eps_u_hist[i]);
        }
        out
    }

    /// Serialize to JSON (consumed by `agd serve --ols-coeffs`).
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::{arr, num, obj, Value};
        let enc = |rows: &Vec<Vec<f64>>| {
            arr(rows
                .iter()
                .map(|r| arr(r.iter().map(|&v| num(v)).collect()))
                .collect::<Vec<Value>>())
        };
        obj(vec![("beta_c", enc(&self.beta_c)), ("beta_u", enc(&self.beta_u))])
    }

    pub fn from_json(v: &crate::util::json::Value) -> Option<OlsCoeffs> {
        let dec = |v: &crate::util::json::Value| -> Option<Vec<Vec<f64>>> {
            v.as_arr()?.iter().map(|r| r.as_f64_vec()).collect()
        };
        Some(OlsCoeffs {
            beta_c: dec(v.get("beta_c")?)?,
            beta_u: dec(v.get("beta_u")?)?,
        })
    }
}

/// Fit per-step OLS coefficients (Eq. 8) on recorded trajectories.
///
/// Step 0 (t = T) has exactly one regressor (the conditional score at T).
/// Ridge `lambda` (default tiny) keeps the Gram matrix well-conditioned when
/// regressors are nearly collinear — which they are by design: that
/// regularity is the paper's observation.
pub fn fit(trajectories: &[ScoreTrajectory], lambda: f64) -> OlsCoeffs {
    assert!(!trajectories.is_empty());
    let steps = trajectories[0].steps();
    for tr in trajectories {
        assert_eq!(tr.steps(), steps, "trajectory length mismatch");
        assert_eq!(tr.eps_u.len(), steps);
    }
    let mut beta_c = Vec::with_capacity(steps);
    let mut beta_u = Vec::with_capacity(steps);
    for t in 0..steps {
        let k = (t + 1) + t; // cond 0..=t, uncond 0..t
        let mut gram = vec![0.0f64; k * k];
        let mut rhs = vec![0.0f64; k];
        for tr in trajectories {
            // regressor views in fixed order: eps_c[0..=t], eps_u[0..t]
            let regs: Vec<&Tensor> = tr.eps_c[..=t]
                .iter()
                .chain(tr.eps_u[..t].iter())
                .collect();
            let y = &tr.eps_u[t];
            for a in 0..k {
                for b in a..k {
                    let dot = dot_f64(&regs[a].data, &regs[b].data);
                    gram[a * k + b] += dot;
                    gram[b * k + a] = gram[a * k + b];
                }
                rhs[a] += dot_f64(&regs[a].data, &y.data);
            }
            // symmetric fill done in-loop above
        }
        for a in 0..k {
            gram[a * k + a] += lambda;
        }
        let sol = linalg::solve_spd(&gram, &rhs, k).expect("singular Gram matrix in OLS fit");
        beta_c.push(sol[..t + 1].to_vec());
        beta_u.push(sol[t + 1..].to_vec());
    }
    OlsCoeffs { beta_c, beta_u }
}

/// Per-step MSE of the fitted estimator on a set of trajectories with
/// *ground-truth* history (Fig. 15's evaluation protocol).
pub fn eval_mse(coeffs: &OlsCoeffs, trajectories: &[ScoreTrajectory]) -> Vec<f64> {
    let steps = coeffs.steps();
    let mut out = vec![0.0; steps];
    for t in 0..steps {
        let mut acc = 0.0;
        for tr in trajectories {
            let pred = coeffs.predict(t, &tr.eps_c, &tr.eps_u);
            acc += pred.mse(&tr.eps_u[t]);
        }
        out[t] = acc / trajectories.len() as f64;
    }
    out
}

fn dot_f64(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_traj(rng: &mut Rng, steps: usize, dim: usize) -> ScoreTrajectory {
        ScoreTrajectory {
            eps_c: (0..steps)
                .map(|_| Tensor::new(vec![dim], rng.normal_vec(dim)))
                .collect(),
            eps_u: (0..steps)
                .map(|_| Tensor::new(vec![dim], rng.normal_vec(dim)))
                .collect(),
        }
    }

    /// Trajectories where eps_u[t] follows a linear recurrence on the history
    /// *plus an independent innovation* — without the innovation the
    /// regressors are exactly collinear (each eps_u[t] lies in the span of
    /// the other regressors) and the Gram matrix is singular, which is also
    /// why `fit` takes a ridge term for the real (highly regular) data.
    fn planted_traj(rng: &mut Rng, steps: usize, dim: usize) -> ScoreTrajectory {
        const NOISE: f32 = 0.05;
        let mut tr = random_traj(rng, steps, dim);
        for t in 0..steps {
            // planted rule: eps_u[t] = 0.8*eps_c[t] + 0.2*eps_u[t-1] + η_t
            let mut y = Tensor::new(vec![dim], rng.normal_vec(dim));
            y.scale(NOISE);
            y.axpy(0.8, &tr.eps_c[t]);
            if t > 0 {
                let prev = tr.eps_u[t - 1].clone();
                y.axpy(0.2, &prev);
            }
            tr.eps_u[t] = y;
        }
        tr
    }

    #[test]
    fn recovers_planted_coefficients() {
        let mut rng = Rng::new(0);
        let trajs: Vec<_> = (0..40).map(|_| planted_traj(&mut rng, 6, 32)).collect();
        let coeffs = fit(&trajs, 1e-6);
        // step 3: beta_c[3] should be ~0.8 on the last cond, beta_u ~0.2 last
        let bc = &coeffs.beta_c[3];
        let bu = &coeffs.beta_u[3];
        assert!((bc[3] - 0.8).abs() < 0.05, "{bc:?}");
        assert!((bu[2] - 0.2).abs() < 0.05, "{bu:?}");
        // residual MSE ≈ innovation variance (0.05² = 0.0025)
        let mse = eval_mse(&coeffs, &trajs);
        assert!(mse.iter().all(|&m| m < 0.01), "{mse:?}");
    }

    #[test]
    fn generalizes_to_heldout_planted_data() {
        let mut rng = Rng::new(1);
        let train: Vec<_> = (0..40).map(|_| planted_traj(&mut rng, 5, 16)).collect();
        let test: Vec<_> = (0..10).map(|_| planted_traj(&mut rng, 5, 16)).collect();
        let coeffs = fit(&train, 1e-6);
        let mse = eval_mse(&coeffs, &test);
        assert!(mse.iter().all(|&m| m < 0.02), "{mse:?}");
    }

    #[test]
    fn random_targets_have_nonzero_error() {
        let mut rng = Rng::new(2);
        let trajs: Vec<_> = (0..10).map(|_| random_traj(&mut rng, 4, 16)).collect();
        let coeffs = fit(&trajs, 1e-6);
        let mse = eval_mse(&coeffs, &trajs);
        // independent gaussian targets can't be predicted: mse ≈ var = 1
        assert!(mse.iter().skip(1).all(|&m| m > 0.3), "{mse:?}");
    }

    #[test]
    fn coefficient_counts_match_eq8() {
        let mut rng = Rng::new(3);
        let trajs: Vec<_> = (0..5).map(|_| random_traj(&mut rng, 7, 8)).collect();
        let coeffs = fit(&trajs, 1e-6);
        for t in 0..7 {
            assert_eq!(coeffs.beta_c[t].len(), t + 1);
            assert_eq!(coeffs.beta_u[t].len(), t);
        }
    }

    #[test]
    fn identity_coefficients_predict_the_conditional_score() {
        let coeffs = OlsCoeffs::identity(4);
        assert_eq!(coeffs.steps(), 4);
        let mut rng = Rng::new(6);
        let tr = random_traj(&mut rng, 4, 8);
        for t in 0..4 {
            let pred = coeffs.predict(t, &tr.eps_c, &tr.eps_u);
            assert_eq!(pred.data, tr.eps_c[t].data, "step {t}");
        }
        // shape contract matches Eq. 8
        for t in 0..4 {
            assert_eq!(coeffs.beta_c[t].len(), t + 1);
            assert_eq!(coeffs.beta_u[t].len(), t);
        }
    }

    #[test]
    fn json_roundtrip() {
        let mut rng = Rng::new(4);
        let trajs: Vec<_> = (0..5).map(|_| planted_traj(&mut rng, 4, 8)).collect();
        let coeffs = fit(&trajs, 1e-9);
        let v = coeffs.to_json();
        let text = crate::util::json::to_string(&v);
        let back = OlsCoeffs::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        for t in 0..4 {
            for (a, b) in coeffs.beta_c[t].iter().zip(&back.beta_c[t]) {
                assert!((a - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn predict_accepts_estimated_history() {
        // autoregressive use: pass estimates instead of ground truth — the
        // shape contract must hold (only first t entries of eps_u consumed).
        let mut rng = Rng::new(5);
        let trajs: Vec<_> = (0..8).map(|_| planted_traj(&mut rng, 4, 8)).collect();
        let coeffs = fit(&trajs, 1e-9);
        let est_hist: Vec<Tensor> = (0..2)
            .map(|_| Tensor::new(vec![8], rng.normal_vec(8)))
            .collect();
        let pred = coeffs.predict(2, &trajs[0].eps_c, &est_hist);
        assert_eq!(pred.len(), 8);
    }
}
