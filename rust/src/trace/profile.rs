//! `agd profile` — turn a drained spans capture into human- and
//! tool-readable profiles:
//!
//! * [`chrome_trace`] — Chrome trace-event JSON (the `traceEvents`
//!   format), loadable at `chrome://tracing` or <https://ui.perfetto.dev>.
//!   Lifecycle spans become complete events (`"ph": "X"`) with
//!   `pid = shard`, `tid = request id`; guidance decisions become
//!   thread-scoped instant events (`"ph": "i"`).
//! * [`stage_summaries`] — per-stage latency distribution (p50/p95/p99)
//!   over every span's duration, in [`Stage::ALL`] order.
//! * [`policy_ledger`] — per-policy *realized* NFE savings, summed from
//!   each request's final guidance event (`"final": true`); `saved`
//!   matches the engine's `nfes_saved_total{policy}` counter because
//!   both compute `max_nfes - nfes` at completion.
//!
//! All three consume the parsed event objects from
//! [`super::parse_capture`] — they tolerate (skip) malformed entries so
//! a partially-overwritten ring still profiles.

use std::collections::BTreeMap;

use crate::perfstat::Summary;
use crate::trace::Stage;
use crate::util::json::{self, Value};

/// One policy's row in the realized-savings ledger.
#[derive(Debug, Clone, PartialEq)]
pub struct LedgerRow {
    pub policy: String,
    /// Requests that reached their final step in this capture.
    pub requests: usize,
    /// NFEs actually spent across those requests.
    pub nfes: u64,
    /// Worst-case NFE budget across those requests.
    pub max_nfes: u64,
    /// `max_nfes - nfes` — realized savings vs. the policy's own budget.
    pub saved: u64,
    /// Requests whose policy fired truncation at some step.
    pub truncated: usize,
}

impl LedgerRow {
    pub fn row(&self) -> Vec<String> {
        vec![
            self.policy.clone(),
            self.requests.to_string(),
            self.nfes.to_string(),
            self.max_nfes.to_string(),
            self.saved.to_string(),
            self.truncated.to_string(),
        ]
    }
}

fn get_u64(v: &Value, key: &str) -> Option<u64> {
    v.get(key).and_then(Value::as_f64).map(|f| f as u64)
}

/// Chrome trace-event JSON over the whole capture. Unknown or malformed
/// entries are skipped, not fatal.
pub fn chrome_trace(events: &[Value]) -> Value {
    let mut rows = Vec::new();
    for ev in events {
        if let Some(row) = chrome_event(ev) {
            rows.push(row);
        }
    }
    json::obj(vec![
        ("traceEvents", Value::Arr(rows)),
        ("displayTimeUnit", json::s("ms")),
    ])
}

fn chrome_event(ev: &Value) -> Option<Value> {
    let shard = get_u64(ev, "shard").unwrap_or(0) as f64;
    let req = get_u64(ev, "req")? as f64;
    match ev.get("type").and_then(Value::as_str)? {
        "span" => {
            let stage = ev.get("stage").and_then(Value::as_str)?;
            Some(json::obj(vec![
                ("name", json::s(stage)),
                ("cat", json::s("lifecycle")),
                ("ph", json::s("X")),
                ("ts", json::num(get_u64(ev, "start_us")? as f64)),
                ("dur", json::num(get_u64(ev, "dur_us")? as f64)),
                ("pid", json::num(shard)),
                ("tid", json::num(req)),
            ]))
        }
        "guidance" => {
            let mut args: Vec<(&str, Value)> = Vec::new();
            for key in ["policy", "evals"] {
                if let Some(s) = ev.get(key).and_then(Value::as_str) {
                    args.push((key, json::s(s)));
                }
            }
            for key in ["step", "nfes", "baseline_nfes", "max_nfes"] {
                if let Some(n) = ev.get(key).and_then(Value::as_f64) {
                    args.push((key, json::num(n)));
                }
            }
            if let Some(g) = ev.get("gamma").and_then(Value::as_f64) {
                args.push(("gamma", json::num(g)));
            }
            for key in ["truncated", "final"] {
                if let Some(b) = ev.get(key).and_then(Value::as_bool) {
                    args.push((key, Value::Bool(b)));
                }
            }
            Some(json::obj(vec![
                ("name", json::s("guidance")),
                ("cat", json::s("guidance")),
                ("ph", json::s("i")),
                ("s", json::s("t")),
                ("ts", json::num(get_u64(ev, "at_us")? as f64)),
                ("pid", json::num(shard)),
                ("tid", json::num(req)),
                ("args", json::obj(args)),
            ]))
        }
        _ => None,
    }
}

/// Per-stage duration summaries (ms), in lifecycle order; stages absent
/// from the capture are omitted.
pub fn stage_summaries(events: &[Value]) -> Vec<Summary> {
    let mut by_stage: BTreeMap<&str, Vec<f64>> = BTreeMap::new();
    for ev in events {
        if ev.get("type").and_then(Value::as_str) != Some("span") {
            continue;
        }
        let (Some(stage), Some(dur)) = (
            ev.get("stage").and_then(Value::as_str),
            get_u64(ev, "dur_us"),
        ) else {
            continue;
        };
        by_stage.entry(stage).or_default().push(dur as f64 / 1e3);
    }
    let mut out = Vec::new();
    for st in Stage::ALL {
        if let Some(samples) = by_stage.get(st.name()) {
            out.push(Summary::from_samples_ms(st.name(), samples));
        }
    }
    out
}

/// The realized-savings ledger: one row per policy, from final guidance
/// events only (in-flight requests would otherwise count phantom
/// savings). Truncation is counted per request, whichever step it fired
/// at.
pub fn policy_ledger(events: &[Value]) -> Vec<LedgerRow> {
    let mut rows: BTreeMap<String, LedgerRow> = BTreeMap::new();
    // (policy, shard, req) -> truncation seen at any step
    let mut truncated: BTreeMap<(String, u64, u64), bool> = BTreeMap::new();
    for ev in events {
        if ev.get("type").and_then(Value::as_str) != Some("guidance") {
            continue;
        }
        let Some(policy) = ev.get("policy").and_then(Value::as_str) else {
            continue;
        };
        let key = (
            policy.to_owned(),
            get_u64(ev, "shard").unwrap_or(0),
            get_u64(ev, "req").unwrap_or(0),
        );
        if ev.get("truncated").and_then(Value::as_bool) == Some(true) {
            truncated.insert(key.clone(), true);
        }
        if ev.get("final").and_then(Value::as_bool) != Some(true) {
            continue;
        }
        let (Some(nfes), Some(max_nfes)) = (get_u64(ev, "nfes"), get_u64(ev, "max_nfes"))
        else {
            continue;
        };
        let row = rows.entry(policy.to_owned()).or_insert_with(|| LedgerRow {
            policy: policy.to_owned(),
            requests: 0,
            nfes: 0,
            max_nfes: 0,
            saved: 0,
            truncated: 0,
        });
        row.requests += 1;
        row.nfes += nfes;
        row.max_nfes += max_nfes;
        row.saved += max_nfes.saturating_sub(nfes);
        if truncated.get(&key).copied().unwrap_or(false) {
            row.truncated += 1;
        }
    }
    rows.into_values().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{event_to_json, Event, EvalSet, Stage};

    fn span_v(req: u64, stage: Stage, start_us: u64, dur_us: u64) -> Value {
        event_to_json(
            &Event::Span {
                req,
                stage,
                start_us,
                dur_us,
            },
            0,
            &[],
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn guidance_v(
        req: u64,
        step: u32,
        nfes: u32,
        max_nfes: u32,
        truncated: bool,
        last: bool,
    ) -> Value {
        event_to_json(
            &Event::Guidance {
                req,
                policy: 0,
                at_us: 10 * (step as u64 + 1),
                step,
                evals: EvalSet::CondUncond,
                gamma: 0.95,
                nfes,
                baseline: 2 * (step + 1),
                max_nfes,
                truncated,
                last,
            },
            0,
            &["ag(s=2)".to_owned()],
        )
    }

    #[test]
    fn chrome_trace_emits_complete_and_instant_events() {
        let events = vec![
            span_v(1, Stage::Denoise, 100, 40),
            guidance_v(1, 0, 2, 16, false, false),
            Value::Bool(true), // malformed entries are skipped
        ];
        let v = chrome_trace(&events);
        let rows = v.req("traceEvents").as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].req("ph").as_str(), Some("X"));
        assert_eq!(rows[0].req("name").as_str(), Some("denoise"));
        assert_eq!(rows[0].req("ts").as_usize(), Some(100));
        assert_eq!(rows[0].req("dur").as_usize(), Some(40));
        assert_eq!(rows[1].req("ph").as_str(), Some("i"));
        assert_eq!(rows[1].req("args").req("policy").as_str(), Some("ag(s=2)"));
        // the export is valid JSON end to end
        let text = json::to_string(&v);
        assert!(json::parse(&text).is_ok());
    }

    #[test]
    fn stage_summaries_group_by_stage_in_lifecycle_order() {
        let events = vec![
            span_v(1, Stage::Denoise, 0, 2_000),
            span_v(2, Stage::Denoise, 10, 4_000),
            span_v(1, Stage::Queue, 0, 1_000),
            guidance_v(1, 0, 2, 16, false, false),
        ];
        let sums = stage_summaries(&events);
        assert_eq!(sums.len(), 2);
        assert_eq!(sums[0].name, "queue", "lifecycle order, not alphabetical");
        assert_eq!(sums[1].name, "denoise");
        assert_eq!(sums[1].iters, 2);
        assert!((sums[1].mean_ms - 3.0).abs() < 1e-9);
    }

    #[test]
    fn ledger_sums_final_events_and_counts_truncation() {
        let events = vec![
            // request 1: truncated mid-flight, finished at 12/16
            guidance_v(1, 2, 6, 16, true, false),
            guidance_v(1, 7, 12, 16, false, true),
            // request 2: full budget, never truncated
            guidance_v(2, 7, 16, 16, false, true),
            // request 3: still in flight — must not count
            guidance_v(3, 1, 4, 16, false, false),
        ];
        let rows = policy_ledger(&events);
        assert_eq!(rows.len(), 1);
        let r = &rows[0];
        assert_eq!(r.policy, "ag(s=2)");
        assert_eq!(r.requests, 2);
        assert_eq!(r.nfes, 28);
        assert_eq!(r.max_nfes, 32);
        assert_eq!(r.saved, 4);
        assert_eq!(r.truncated, 1);
    }

    #[test]
    fn ledger_is_empty_without_final_events() {
        let events = vec![guidance_v(1, 0, 2, 16, false, false)];
        assert!(policy_ledger(&events).is_empty());
        assert!(policy_ledger(&[]).is_empty());
    }
}
