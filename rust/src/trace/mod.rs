//! §Observability: request-lifecycle span recording & guidance-decision
//! profiling.
//!
//! Distinct from [`crate::chaos::trace`], which captures *traffic* for
//! replay — this module captures *where a request spent its time* and
//! *what the guidance policy actually decided*, so the paper's efficiency
//! claim (AG cuts NFEs without quality loss) is observable per request
//! and per step, not just as aggregate counters.
//!
//! Two event kinds land in one per-shard ring:
//!
//! * **Lifecycle spans** ([`Event::Span`]) — one per stage a request
//!   passes through: `admission → placement → queue → batch → denoise →
//!   combine → complete` ([`Stage`]). Recorded only for requests that
//!   opted in (`"trace": true` in the server envelope /
//!   `Request::trace`), because the per-step stages (batch, denoise,
//!   combine) repeat every denoising step.
//! * **Guidance decisions** ([`Event::Guidance`]) — one per denoising
//!   step for *every* request: step index, the evaluations the policy
//!   executed ([`EvalSet`]: cond / cond+uncond / extrapolated / …),
//!   gamma (Eq. 7), cumulative NFEs vs. the full-CFG baseline, and
//!   whether the policy's `observe` fired truncation at this step. The
//!   final event of a request carries `last = true` and is what the
//!   [`profile`] ledger sums — by construction it reproduces the
//!   engine's `nfes_saved_total{policy}` counters.
//!
//! # The zero-allocation contract
//!
//! The ring ([`SpanRing`]) is preallocated at engine construction and
//! events are plain `Copy` structs, so recording from the engine's
//! steady-state `pump()` performs **no heap allocation** — the
//! `zero_alloc.rs` / `par_zero_alloc.rs` invariants hold with tracing
//! on. Everything that does allocate (policy-name interning, per-request
//! timeline reservation) happens at request admission; everything that
//! serializes (drains, JSON) happens off the hot path. On overflow the
//! ring overwrites the oldest event and bumps a monotonic `dropped`
//! counter — surfaced as `spans_dropped_total` in `{"cmd": "stats"}` so
//! loss is visible, never silent.
//!
//! # Draining and export
//!
//! [`TraceRecorder::drain`] snapshots the ring into a [`SpanBatch`]
//! (events + the interned policy table + the drop counter); the fleet
//! stamps each batch with its shard id and `{"cmd": "spans"}` serializes
//! them ([`batches_to_json`]). `agd profile --spans FILE` then turns a
//! drained capture into a Chrome trace-event JSON (load it at
//! `chrome://tracing` or <https://ui.perfetto.dev>), a per-stage
//! p50/p95/p99 table, and the per-policy realized-NFE-savings ledger
//! ([`profile`]). See `docs/OBSERVABILITY.md` for the full schema and a
//! walkthrough.

use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::policy::StepPlan;
use crate::util::json::{self, Value};

pub mod profile;

/// Default per-shard ring capacity (events). At ~40 events per traced
/// 8-step request this holds on the order of a hundred traced requests
/// between drains.
pub const DEFAULT_SPAN_CAP: usize = 4096;

/// Cap on the interned policy-name table; admissions past it record
/// under [`OTHER_POLICY`] rather than growing without bound.
pub const MAX_POLICIES: usize = 256;

/// Sentinel policy id for table overflow — resolves to `"other"`.
pub const OTHER_POLICY: u16 = u16::MAX;

/// The seven request-lifecycle stages, in request order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Global admission check at the fleet router.
    Admission,
    /// Router placement decision (shard choice).
    Placement,
    /// Shard queue wait: router hand-off → engine admit.
    Queue,
    /// Batch assembly: packing work items into the batch buffers.
    Batch,
    /// The batched network evaluation (`denoise_into_par`).
    Denoise,
    /// Fused combine+gamma / solver step completion.
    Combine,
    /// Completion bookkeeping and hand-back.
    Complete,
}

impl Stage {
    pub const ALL: [Stage; 7] = [
        Stage::Admission,
        Stage::Placement,
        Stage::Queue,
        Stage::Batch,
        Stage::Denoise,
        Stage::Combine,
        Stage::Complete,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Stage::Admission => "admission",
            Stage::Placement => "placement",
            Stage::Queue => "queue",
            Stage::Batch => "batch",
            Stage::Denoise => "denoise",
            Stage::Combine => "combine",
            Stage::Complete => "complete",
        }
    }

    pub fn parse(s: &str) -> Option<Stage> {
        Stage::ALL.into_iter().find(|st| st.name() == s)
    }
}

/// Which network evaluations a step actually executed — the observable
/// form of a [`StepPlan`] (the OLS coefficients a `LinearGuided` plan
/// carries are not part of the observation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvalSet {
    /// Conditional only (AG after truncation, cond-only baselines).
    Cond,
    /// The classic CFG pair.
    CondUncond,
    /// Conditional evaluated, unconditional *extrapolated* (LINEARAG).
    Extrapolated,
    /// Unconditional only (searched policies may select it).
    Uncond,
    /// The editing triple (Eq. 9).
    EditTriple,
    /// Editing after truncation: the full-conditioned eval only.
    EditCond,
}

impl EvalSet {
    /// Classify the plan a step executed.
    pub fn of(plan: &StepPlan) -> EvalSet {
        match plan {
            StepPlan::Guided { .. } => EvalSet::CondUncond,
            StepPlan::CondOnly => EvalSet::Cond,
            StepPlan::UncondOnly => EvalSet::Uncond,
            StepPlan::LinearGuided { .. } => EvalSet::Extrapolated,
            StepPlan::EditGuided { .. } => EvalSet::EditTriple,
            StepPlan::EditCondOnly => EvalSet::EditCond,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            EvalSet::Cond => "cond",
            EvalSet::CondUncond => "cond+uncond",
            EvalSet::Extrapolated => "extrapolated",
            EvalSet::Uncond => "uncond",
            EvalSet::EditTriple => "edit-triple",
            EvalSet::EditCond => "edit-cond",
        }
    }
}

/// One recorded event. `Copy` + fixed-size on purpose: recording is a
/// slot write into a preallocated ring, never an allocation. Times are
/// microseconds on the owning [`TraceRecorder`]'s clock.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Event {
    /// A lifecycle stage a traced request passed through.
    Span {
        req: u64,
        stage: Stage,
        start_us: u64,
        dur_us: u64,
    },
    /// One guidance decision (one denoising step of one request).
    Guidance {
        req: u64,
        /// Interned policy id ([`TraceRecorder::intern`]).
        policy: u16,
        at_us: u64,
        /// Step index (0-based) the decision applied to.
        step: u32,
        evals: EvalSet,
        /// Gamma (Eq. 7) observed at this step; NaN when the step had no
        /// convergence signal (serialized as `null`).
        gamma: f32,
        /// Cumulative NFEs spent by this request through this step.
        nfes: u32,
        /// Cumulative full-CFG baseline: 2 evals for every step so far.
        baseline: u32,
        /// The policy's worst-case NFE budget for the whole request —
        /// the engine's `nfes_saved` accounting is `max_nfes - nfes`.
        max_nfes: u32,
        /// The policy's `observe` fired truncation at this step.
        truncated: bool,
        /// This is the request's final step (the ledger sums these).
        last: bool,
    },
}

impl Default for Event {
    fn default() -> Event {
        Event::Span {
            req: 0,
            stage: Stage::Admission,
            start_us: 0,
            dur_us: 0,
        }
    }
}

impl Event {
    pub fn req(&self) -> u64 {
        match *self {
            Event::Span { req, .. } | Event::Guidance { req, .. } => req,
        }
    }

    /// Event timestamp (span start / decision instant) in recorder µs.
    pub fn at_us(&self) -> u64 {
        match *self {
            Event::Span { start_us, .. } => start_us,
            Event::Guidance { at_us, .. } => at_us,
        }
    }
}

/// Fixed-capacity overwrite ring of [`Event`]s. The buffer is fully
/// allocated up front; `push` is a slot write (overwriting the oldest
/// event when full and bumping the monotonic `dropped` total).
#[derive(Debug)]
pub struct SpanRing {
    buf: Vec<Event>,
    /// Next write slot.
    head: usize,
    /// Live events (≤ capacity).
    len: usize,
    dropped: u64,
}

impl SpanRing {
    pub fn new(cap: usize) -> SpanRing {
        let cap = cap.max(1);
        SpanRing {
            buf: vec![Event::default(); cap],
            head: 0,
            len: 0,
            dropped: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Total events overwritten before being drained (monotonic — drains
    /// do not reset it).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Record one event — no allocation, ever.
    pub fn push(&mut self, ev: Event) {
        let cap = self.buf.len();
        self.buf[self.head] = ev;
        self.head = (self.head + 1) % cap;
        if self.len == cap {
            self.dropped += 1;
        } else {
            self.len += 1;
        }
    }

    /// Append the live events to `out` oldest-first and clear the ring.
    pub fn drain_into(&mut self, out: &mut Vec<Event>) {
        let cap = self.buf.len();
        let start = (self.head + cap - self.len) % cap;
        out.reserve(self.len);
        for i in 0..self.len {
            out.push(self.buf[(start + i) % cap]);
        }
        self.len = 0;
        self.head = 0;
    }
}

/// Per-shard recorder: the ring, the interned policy-name table, and the
/// clock every event timestamp is measured on. Owned by the engine and
/// only ever touched from the engine thread — no locks on the hot path.
#[derive(Debug)]
pub struct TraceRecorder {
    ring: SpanRing,
    policies: Vec<String>,
    epoch: Instant,
}

impl TraceRecorder {
    pub fn new(cap: usize) -> TraceRecorder {
        TraceRecorder {
            ring: SpanRing::new(cap),
            policies: Vec::with_capacity(MAX_POLICIES.min(64)),
            epoch: Instant::now(),
        }
    }

    /// Microseconds since the recorder's epoch — the clock all events
    /// (and the engine's stage histograms) share.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// An [`Instant`] on the recorder clock (0 for instants predating
    /// the epoch — only reachable if a request outlived an engine swap).
    pub fn us_since_epoch(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_micros() as u64
    }

    /// Intern a policy display name (admission-time only — this is the
    /// one place the recorder may allocate). Past [`MAX_POLICIES`]
    /// distinct names, returns [`OTHER_POLICY`].
    pub fn intern(&mut self, name: &str) -> u16 {
        if let Some(i) = self.policies.iter().position(|p| p == name) {
            return i as u16;
        }
        if self.policies.len() >= MAX_POLICIES {
            return OTHER_POLICY;
        }
        self.policies.push(name.to_owned());
        (self.policies.len() - 1) as u16
    }

    /// The interned policy-name table — for serializing events without
    /// draining the ring (the engine's per-request timelines).
    pub fn policies(&self) -> &[String] {
        &self.policies
    }

    pub fn policy_name(&self, id: u16) -> &str {
        self.policies
            .get(id as usize)
            .map(String::as_str)
            .unwrap_or("other")
    }

    /// Record one event into the ring — alloc-free.
    pub fn record(&mut self, ev: Event) {
        self.ring.push(ev);
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    pub fn dropped(&self) -> u64 {
        self.ring.dropped()
    }

    /// Snapshot and clear the ring. The batch carries a copy of the
    /// policy table so guidance events stay resolvable after transport.
    pub fn drain(&mut self) -> SpanBatch {
        let mut events = Vec::new();
        self.ring.drain_into(&mut events);
        SpanBatch {
            shard: 0,
            events,
            policies: self.policies.clone(),
            dropped: self.ring.dropped(),
        }
    }
}

/// Append `ev` only while spare capacity remains — the per-request
/// timeline buffers are reserved at admission and must never reallocate
/// inside `pump()`.
pub fn push_capped(buf: &mut Vec<Event>, ev: Event) {
    if buf.len() < buf.capacity() {
        buf.push(ev);
    }
}

/// A drained ring: events + the policy table that resolves guidance
/// ids + the shard's monotonic drop total. `shard` is stamped by the
/// fleet when batches from multiple replicas are merged.
#[derive(Debug, Clone)]
pub struct SpanBatch {
    pub shard: usize,
    pub events: Vec<Event>,
    pub policies: Vec<String>,
    pub dropped: u64,
}

impl SpanBatch {
    /// Serialize every event, stamped with this batch's shard id.
    pub fn events_json(&self) -> Vec<Value> {
        self.events
            .iter()
            .map(|ev| event_to_json(ev, self.shard, &self.policies))
            .collect()
    }
}

fn policy_label(policies: &[String], id: u16) -> &str {
    policies
        .get(id as usize)
        .map(String::as_str)
        .unwrap_or("other")
}

/// The wire/file schema of one event (see `docs/OBSERVABILITY.md`).
pub fn event_to_json(ev: &Event, shard: usize, policies: &[String]) -> Value {
    match *ev {
        Event::Span {
            req,
            stage,
            start_us,
            dur_us,
        } => json::obj(vec![
            ("type", json::s("span")),
            ("req", json::num(req as f64)),
            ("shard", json::num(shard as f64)),
            ("stage", json::s(stage.name())),
            ("start_us", json::num(start_us as f64)),
            ("dur_us", json::num(dur_us as f64)),
        ]),
        Event::Guidance {
            req,
            policy,
            at_us,
            step,
            evals,
            gamma,
            nfes,
            baseline,
            max_nfes,
            truncated,
            last,
        } => json::obj(vec![
            ("type", json::s("guidance")),
            ("req", json::num(req as f64)),
            ("shard", json::num(shard as f64)),
            ("policy", json::s(policy_label(policies, policy))),
            ("at_us", json::num(at_us as f64)),
            ("step", json::num(step as f64)),
            ("evals", json::s(evals.name())),
            (
                "gamma",
                if gamma.is_finite() {
                    json::num(gamma as f64)
                } else {
                    Value::Null
                },
            ),
            ("nfes", json::num(nfes as f64)),
            ("baseline_nfes", json::num(baseline as f64)),
            ("max_nfes", json::num(max_nfes as f64)),
            ("truncated", Value::Bool(truncated)),
            ("final", Value::Bool(last)),
        ]),
    }
}

/// The `{"cmd": "spans"}` reply body: all events across shards (each
/// stamped with its shard) plus the summed drop total.
pub fn batches_to_json(batches: &[SpanBatch]) -> Value {
    let mut events = Vec::new();
    let mut dropped = 0u64;
    for b in batches {
        events.extend(b.events_json());
        dropped += b.dropped;
    }
    json::obj(vec![
        ("spans", Value::Arr(events)),
        ("dropped", json::num(dropped as f64)),
    ])
}

/// Parse a spans capture: a `{"cmd": "spans"}` reply object, a bare
/// JSON array of events, a single event object, or JSONL (one event or
/// reply object per line). The formats compose so `agd profile` accepts
/// whatever a user saved — a raw netcat reply line or a concatenation
/// of several drains.
pub fn parse_capture(text: &str) -> Result<Vec<Value>> {
    let trimmed = text.trim();
    if trimmed.is_empty() {
        return Ok(Vec::new());
    }
    if let Ok(v) = json::parse(trimmed) {
        return capture_value_events(v);
    }
    // JSONL: one document per line
    let mut out = Vec::new();
    for (idx, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = json::parse(line).map_err(|e| anyhow!("line {}: {e}", idx + 1))?;
        out.extend(capture_value_events(v)?);
    }
    Ok(out)
}

fn capture_value_events(v: Value) -> Result<Vec<Value>> {
    match v {
        Value::Arr(a) => Ok(a),
        Value::Obj(_) => {
            if let Some(a) = v.get("spans").and_then(Value::as_arr) {
                Ok(a.to_vec())
            } else if v.get("type").is_some() {
                Ok(vec![v])
            } else {
                Err(anyhow!(
                    "object is neither a spans reply nor an event (no `spans`/`type` key)"
                ))
            }
        }
        _ => Err(anyhow!("expected a spans object, array, or JSONL of events")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(req: u64, stage: Stage, start_us: u64, dur_us: u64) -> Event {
        Event::Span {
            req,
            stage,
            start_us,
            dur_us,
        }
    }

    fn guidance(req: u64, policy: u16, step: u32, nfes: u32, last: bool) -> Event {
        Event::Guidance {
            req,
            policy,
            at_us: 100 * (step as u64 + 1),
            step,
            evals: EvalSet::CondUncond,
            gamma: 0.9,
            nfes,
            baseline: 2 * (step + 1),
            max_nfes: 16,
            truncated: false,
            last,
        }
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let mut r = SpanRing::new(3);
        for i in 0..5u64 {
            r.push(span(i, Stage::Queue, i, 1));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped(), 2);
        let mut out = Vec::new();
        r.drain_into(&mut out);
        let ids: Vec<u64> = out.iter().map(Event::req).collect();
        assert_eq!(ids, vec![2, 3, 4], "oldest-first, oldest two overwritten");
        assert!(r.is_empty());
        // the drop total is monotonic across drains
        assert_eq!(r.dropped(), 2);
        r.push(span(9, Stage::Queue, 9, 1));
        assert_eq!(r.len(), 1);
        assert_eq!(r.dropped(), 2);
    }

    #[test]
    fn ring_drains_in_order_below_capacity() {
        let mut r = SpanRing::new(8);
        for i in 0..3u64 {
            r.push(span(i, Stage::Batch, i * 10, 1));
        }
        let mut out = Vec::new();
        r.drain_into(&mut out);
        assert_eq!(out.iter().map(Event::req).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn recorder_interns_policies_up_to_the_cap() {
        let mut t = TraceRecorder::new(4);
        let a = t.intern("cfg(s=2)");
        let b = t.intern("ag(s=2,gamma_bar=0.99)");
        assert_eq!(t.intern("cfg(s=2)"), a, "repeat lookups hit the same id");
        assert_ne!(a, b);
        assert_eq!(t.policy_name(a), "cfg(s=2)");
        for i in 0..MAX_POLICIES {
            t.intern(&format!("p{i}"));
        }
        assert_eq!(t.intern("one-too-many"), OTHER_POLICY);
        assert_eq!(t.policy_name(OTHER_POLICY), "other");
    }

    #[test]
    fn events_round_trip_through_json() {
        let mut t = TraceRecorder::new(8);
        let pid = t.intern("ag(s=2)");
        t.record(span(7, Stage::Denoise, 120, 45));
        t.record(guidance(7, pid, 3, 8, true));
        let mut batch = t.drain();
        batch.shard = 2;
        let rows = batch.events_json();
        assert_eq!(rows.len(), 2);
        let sp = &rows[0];
        assert_eq!(sp.req("type").as_str(), Some("span"));
        assert_eq!(sp.req("stage").as_str(), Some("denoise"));
        assert_eq!(sp.req("shard").as_usize(), Some(2));
        assert_eq!(sp.req("start_us").as_usize(), Some(120));
        assert_eq!(sp.req("dur_us").as_usize(), Some(45));
        let g = &rows[1];
        assert_eq!(g.req("type").as_str(), Some("guidance"));
        assert_eq!(g.req("policy").as_str(), Some("ag(s=2)"));
        assert_eq!(g.req("step").as_usize(), Some(3));
        assert_eq!(g.req("evals").as_str(), Some("cond+uncond"));
        assert_eq!(g.req("nfes").as_usize(), Some(8));
        assert_eq!(g.req("baseline_nfes").as_usize(), Some(8));
        assert_eq!(g.req("max_nfes").as_usize(), Some(16));
        assert_eq!(g.req("final").as_bool(), Some(true));
        // the serialized line is valid JSON end to end
        let line = json::to_string(&batches_to_json(&[batch]));
        let back = json::parse(&line).unwrap();
        assert_eq!(back.req("spans").as_arr().unwrap().len(), 2);
        assert_eq!(back.req("dropped").as_usize(), Some(0));
    }

    #[test]
    fn nan_gamma_serializes_as_null() {
        let ev = Event::Guidance {
            req: 1,
            policy: 0,
            at_us: 5,
            step: 0,
            evals: EvalSet::Cond,
            gamma: f32::NAN,
            nfes: 1,
            baseline: 2,
            max_nfes: 16,
            truncated: false,
            last: false,
        };
        let v = event_to_json(&ev, 0, &["cfg".to_owned()]);
        assert_eq!(v.req("gamma"), &Value::Null);
        // and the emitted text stays parseable (a bare NaN would not)
        assert!(json::parse(&json::to_string(&v)).is_ok());
    }

    #[test]
    fn eval_set_classifies_every_plan() {
        assert_eq!(EvalSet::of(&StepPlan::Guided { s: 2.0 }), EvalSet::CondUncond);
        assert_eq!(EvalSet::of(&StepPlan::CondOnly), EvalSet::Cond);
        assert_eq!(EvalSet::of(&StepPlan::UncondOnly), EvalSet::Uncond);
        assert_eq!(EvalSet::of(&StepPlan::EditCondOnly), EvalSet::EditCond);
        assert_eq!(
            EvalSet::of(&StepPlan::EditGuided {
                s_text: 7.5,
                s_img: 1.5
            }),
            EvalSet::EditTriple
        );
    }

    #[test]
    fn stage_names_round_trip() {
        for st in Stage::ALL {
            assert_eq!(Stage::parse(st.name()), Some(st));
        }
        assert_eq!(Stage::parse("nonsense"), None);
    }

    #[test]
    fn push_capped_never_grows_the_buffer() {
        let mut buf: Vec<Event> = Vec::with_capacity(2);
        let cap = buf.capacity();
        for i in 0..5u64 {
            push_capped(&mut buf, span(i, Stage::Queue, i, 1));
        }
        assert_eq!(buf.len(), 2);
        assert_eq!(buf.capacity(), cap);
    }

    #[test]
    fn parse_capture_accepts_reply_array_and_jsonl() {
        let mut t = TraceRecorder::new(8);
        let pid = t.intern("cfg");
        t.record(span(1, Stage::Queue, 0, 10));
        t.record(guidance(1, pid, 0, 2, true));
        let batch = t.drain();
        let reply = json::to_string(&batches_to_json(&[batch.clone()]));
        assert_eq!(parse_capture(&reply).unwrap().len(), 2);

        let arr = json::to_string(&Value::Arr(batch.events_json()));
        assert_eq!(parse_capture(&arr).unwrap().len(), 2);

        let jsonl: Vec<String> = batch
            .events_json()
            .iter()
            .map(json::to_string)
            .collect();
        assert_eq!(parse_capture(&jsonl.join("\n")).unwrap().len(), 2);
        // two reply lines concatenate (several drains appended to a file)
        let two = format!("{reply}\n{reply}\n");
        assert_eq!(parse_capture(&two).unwrap().len(), 4);

        assert_eq!(parse_capture("  ").unwrap().len(), 0);
        assert!(parse_capture("{\"neither\": 1}").is_err());
        assert!(parse_capture("true").is_err());
    }
}
