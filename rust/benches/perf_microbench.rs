//! §Perf microbenchmarks (not a paper figure): the quantities the
//! optimization pass iterates on.
//!
//!  * denoiser executable latency per batch bucket (L2 hot path),
//!  * amortized per-item cost vs bucket (batching payoff),
//!  * L3 scheduler overhead: engine loop on a near-zero-cost backend,
//!  * host combine+solve vs the device guide/solver executables (ablation:
//!    where should the tiny per-step math live?).
//!
//! Run: `cargo bench --bench perf_microbench`

use adaptive_guidance::backend::{Backend, EvalInput, GmmBackend};
use adaptive_guidance::coordinator::engine::Engine;
use adaptive_guidance::coordinator::policy::{Cfg, Policy};
use adaptive_guidance::coordinator::request::Request;
use adaptive_guidance::coordinator::solver;
use adaptive_guidance::perfstat::{bench, print_summaries};
use adaptive_guidance::runtime;
use adaptive_guidance::sim::gmm::Gmm;
use adaptive_guidance::tensor::Tensor;
use adaptive_guidance::util::cli::Args;
use adaptive_guidance::util::rng::Rng;

fn main() {
    let args = Args::from_env();
    let iters = args.usize("iters", 30);
    let mut rows = Vec::new();

    // ---- L3 scheduler overhead: GMM backend is ~free, so the per-item time
    // is almost pure engine bookkeeping.
    {
        let mut engine = Engine::new(GmmBackend::new(Gmm::axes(768, 4, 3.0, 0.05))).expect("engine");
        let mut id = 0u64;
        let s = bench("L3 engine loop (16 req x 10 steps, gmm)", 2, iters, || {
            let reqs: Vec<Request> = (0..16)
                .map(|i| {
                    id += 1;
                    Request::new(id, "gmm", vec![1 + (i % 4) as i32, 0, 0, 0],
                                 id, 10, Cfg { s: 2.0 }.into_ref())
                })
                .collect();
            engine.run(reqs).unwrap();
        });
        let per_item_us = s.p50_ms * 1e3 / (16.0 * 10.0 * 2.0);
        rows.push(s);
        println!("scheduler overhead: ~{per_item_us:.1} us per NFE item (incl. gmm math)\n");
    }

    // ---- host combine + solve (the per-step non-NFE math)
    {
        let mut rng = Rng::new(1);
        let c = Tensor::new(vec![768], rng.normal_vec(768));
        let u = Tensor::new(vec![768], rng.normal_vec(768));
        let x = rng.normal_vec(768);
        let x0p = rng.normal_vec(768);
        let coefs = solver::fold_coefs(0.6, 0.55, Some(0.65));
        rows.push(bench("host combine+cosine+solve (768d)", 10, iters * 10, || {
            let eps = Tensor::cfg_combine(&c, &u, 7.5);
            std::hint::black_box(c.cosine(&u));
            std::hint::black_box(solver::apply_step(&x, &eps.data, &x0p, &coefs));
        }));
    }

    // ---- PJRT paths (need artifacts)
    if let Some(mut be) = runtime::try_load_default() {
        let mut rng = Rng::new(2);
        for &b in &[1usize, 2, 4, 8, 16] {
            let items: Vec<EvalInput> = (0..b)
                .map(|i| EvalInput {
                    x: rng.normal_vec(768),
                    t: 0.5,
                    tokens: vec![1 + (i % 4) as i32, 1, 1, 1],
                })
                .collect();
            be.denoise("dit_b", &items).unwrap(); // warm compile
            let s = bench(&format!("denoiser dit_b bucket {b}"), 3, iters, || {
                std::hint::black_box(be.denoise("dit_b", &items).unwrap());
            });
            println!(
                "bucket {b}: {:.3} ms/batch = {:.3} ms/NFE",
                s.p50_ms,
                s.p50_ms / b as f64
            );
            rows.push(s);
        }
        // device guide vs host combine
        let ec = rng.normal_vec(768);
        let eu = rng.normal_vec(768);
        be.run_guide(&ec, &eu, &[7.5]).unwrap();
        rows.push(bench("device guide exec (b1)", 3, iters, || {
            std::hint::black_box(be.run_guide(&ec, &eu, &[7.5]).unwrap());
        }));
        let x = rng.normal_vec(768);
        let x0p = rng.normal_vec(768);
        let carr = [0.9f32, -0.1, 0.05, 1.2, -0.7];
        be.run_solver(&x, &ec, &x0p, &carr).unwrap();
        rows.push(bench("device solver exec (b1)", 3, iters, || {
            std::hint::black_box(be.run_solver(&x, &ec, &x0p, &carr).unwrap());
        }));
    }

    println!();
    print_summaries(&rows);
    println!(
        "\nreading: per-NFE cost should fall with bucket size (batching pays);\n\
         host combine+solve should be far below one denoiser NFE (it is the\n\
         right place for the per-step math — the device round-trip dominates\n\
         the device guide/solver numbers)."
    );
}
