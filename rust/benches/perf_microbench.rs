//! §Perf microbenchmarks (not a paper figure): the quantities the
//! optimization pass iterates on.
//!
//!  * L3 scheduler overhead: engine loop on a near-zero-cost backend, in
//!    two flavours — the packed zero-allocation path (current) and a
//!    legacy adapter emulating the seed path's per-item clones, so every
//!    run carries its own before/after pair,
//!  * host combine+solve vs the device guide/solver executables (ablation:
//!    where should the tiny per-step math live?), fused and unfused,
//!  * denoiser executable latency per batch bucket (L2 hot path),
//!  * amortized per-item cost vs bucket (batching payoff).
//!
//! Run: `cargo bench --bench perf_microbench -- --out BENCH_perf.json`
//! The `--out` dump (`perfstat::summaries_to_json`) is the machine-readable
//! perf trajectory: commit a baseline before an optimization PR and the
//! after-numbers with it.

use adaptive_guidance::backend::{Backend, BatchBuf, BatchOut, EvalInput, GmmBackend};
use adaptive_guidance::coordinator::engine::Engine;
use adaptive_guidance::coordinator::policy::{Cfg, Policy};
use adaptive_guidance::coordinator::request::Request;
use adaptive_guidance::coordinator::solver;
use adaptive_guidance::perfstat::{bench, print_summaries, write_json, Summary};
use adaptive_guidance::runtime;
use adaptive_guidance::sim::gmm::Gmm;
use adaptive_guidance::tensor::{self, Tensor};
use adaptive_guidance::util::cli::Args;
use adaptive_guidance::util::rng::Rng;
use anyhow::Result;

/// Emulates the seed path's *backend-side* per-item traffic on top of the
/// packed interface: every eval row is cloned into owned input vectors,
/// every score is computed through the allocating `Gmm::eps`, and the
/// results pass through an intermediate `Vec<Vec<f32>>` like the old
/// `denoise(&[EvalInput])` return shape. Note this is a **lower bound** on
/// the true pre-refactor cost — the seed coordinator's own per-step
/// allocations (latent clones in `eval_input`, three-pass unfused
/// combine/cosine math, out-of-place solver) still run in their new
/// zero-alloc form here, so the packed-vs-legacy gap understates the full
/// improvement.
struct LegacyVecGmm {
    gmm: Gmm,
    buckets: Vec<usize>,
}

impl Backend for LegacyVecGmm {
    fn flat_in(&self, _: &str) -> usize {
        self.gmm.dim
    }
    fn flat_out(&self, _: &str) -> usize {
        self.gmm.dim
    }
    fn buckets(&self) -> &[usize] {
        &self.buckets
    }
    fn denoise_into(&mut self, _: &str, batch: &BatchBuf, out: &mut BatchOut) -> Result<()> {
        // per-item input clones + allocating eps + Vec<Vec<f32>> results,
        // like the seed backend path
        let results: Vec<Vec<f32>> = (0..batch.len())
            .map(|i| {
                let x = batch.x_row(i).to_vec();
                let toks = batch.token_row(i).to_vec();
                let cond = if toks[0] == 0 {
                    None
                } else {
                    Some((toks[0] - 1) as usize)
                };
                self.gmm.eps(&x, batch.t(i) as f64, cond)
            })
            .collect();
        out.reset(self.gmm.dim, batch.len());
        for (i, eps) in results.iter().enumerate() {
            out.row_mut(i).copy_from_slice(eps);
        }
        Ok(())
    }
    fn models(&self) -> Vec<String> {
        vec!["gmm".to_owned()]
    }
}

/// One engine-loop measurement: 16 requests × 10 steps of CFG over a
/// near-free analytic backend, so the time is almost pure L3 bookkeeping.
/// `workers` sizes the engine's ExecPool (1 = the serial engine).
fn engine_loop_row<B: Backend>(
    name: &str,
    backend: B,
    iters: usize,
    workers: usize,
) -> (Summary, f64) {
    let mut engine = Engine::new(backend).expect("engine");
    engine.set_workers(workers);
    let mut id = 0u64;
    let s = bench(name, 2, iters, || {
        let reqs: Vec<Request> = (0..16)
            .map(|i| {
                id += 1;
                Request::new(id, "gmm", vec![1 + (i % 4) as i32, 0, 0, 0],
                             id, 10, Cfg { s: 2.0 }.into_ref())
            })
            .collect();
        engine.run(reqs).unwrap();
    });
    let per_nfe_us = s.p50_ms * 1e3 / (16.0 * 10.0 * 2.0);
    (s, per_nfe_us)
}

fn main() {
    let args = Args::from_env();
    let iters = args.usize("iters", 30);
    let mut rows = Vec::new();
    let mut derived: Vec<(&str, f64)> = Vec::new();

    // ---- L3 scheduler overhead, packed (current) vs legacy per-item
    // emulation: the engine-loop row this PR's refactor targets.
    // (the packed workers=1 row doubles as the scaling sweep's baseline)
    let packed_base_per_nfe;
    {
        let (s, per_nfe) = engine_loop_row(
            "L3 engine loop packed (16 req x 10 steps, gmm)",
            GmmBackend::new(Gmm::axes(768, 4, 3.0, 0.05)),
            iters,
            1,
        );
        rows.push(s);
        derived.push(("engine_loop_packed_per_nfe_us", per_nfe));
        packed_base_per_nfe = per_nfe;
        println!("scheduler overhead (packed): ~{per_nfe:.1} us per NFE item (incl. gmm math)");

        let (s, per_nfe) = engine_loop_row(
            "L3 engine loop legacy per-item (16 req x 10 steps, gmm)",
            LegacyVecGmm {
                gmm: Gmm::axes(768, 4, 3.0, 0.05),
                buckets: vec![1, 2, 4, 8, 16],
            },
            iters,
            1,
        );
        rows.push(s);
        derived.push(("engine_loop_legacy_per_nfe_us", per_nfe));
        println!(
            "scheduler overhead (legacy backend emulation, lower bound on the \
             seed cost): ~{per_nfe:.1} us per NFE item\n"
        );
    }

    // ---- worker-pool scaling sweep (§Perf: parallel execution): the
    // same batch-16 GMM workload sharded over 1/2/4/8 lanes. The per-NFE
    // numbers land in the --out JSON as the multi-core perf trajectory;
    // expect ≥2x at 4 workers on a 4-core host (results are bit-identical
    // at every width — only throughput moves).
    {
        // workers=1 is exactly the packed row above — reuse it as the
        // baseline instead of re-timing the same configuration
        let base = packed_base_per_nfe;
        let mut per_nfe_by_workers: Vec<(usize, f64)> = vec![(1, base)];
        derived.push(("engine_loop_workers1_per_nfe_us", base));
        for &w in &[2usize, 4, 8] {
            let (s, per_nfe) = engine_loop_row(
                &format!("L3 engine loop packed workers={w} (16 req x 10 steps, gmm)"),
                GmmBackend::new(Gmm::axes(768, 4, 3.0, 0.05)),
                iters,
                w,
            );
            rows.push(s);
            let key = match w {
                2 => "engine_loop_workers2_per_nfe_us",
                4 => "engine_loop_workers4_per_nfe_us",
                _ => "engine_loop_workers8_per_nfe_us",
            };
            derived.push((key, per_nfe));
            per_nfe_by_workers.push((w, per_nfe));
        }
        println!("worker scaling (per-NFE engine loop, gmm 768d):");
        for &(w, v) in &per_nfe_by_workers {
            println!("  workers={w}: {v:.2} us/NFE  ({:.2}x vs workers=1)", base / v);
            let key = match w {
                2 => Some("engine_loop_workers2_speedup"),
                4 => Some("engine_loop_workers4_speedup"),
                8 => Some("engine_loop_workers8_speedup"),
                _ => None,
            };
            if let Some(key) = key {
                derived.push((key, base / v));
            }
        }
        println!();
    }

    // ---- host combine + solve (the per-step non-NFE math), unfused (seed
    // sequence) vs the fused single-pass kernel
    {
        let mut rng = Rng::new(1);
        let c = Tensor::new(vec![768], rng.normal_vec(768));
        let u = Tensor::new(vec![768], rng.normal_vec(768));
        let x = rng.normal_vec(768);
        let mut x0p = rng.normal_vec(768);
        let coefs = solver::fold_coefs(0.6, 0.55, Some(0.65));
        rows.push(bench("host combine+cosine+solve unfused (768d)", 10, iters * 10, || {
            let eps = Tensor::cfg_combine(&c, &u, 7.5);
            std::hint::black_box(c.cosine(&u));
            std::hint::black_box(solver::apply_step(&x, &eps.data, &x0p, &coefs));
        }));
        let mut eps = vec![0.0f32; 768];
        let mut x_ip = x.clone();
        rows.push(bench("host combine+gamma+solve fused in-place (768d)", 10, iters * 10, || {
            let g = tensor::combine_and_gamma(
                &c.data, &u.data, 7.5, &x_ip,
                coefs.j_x as f32, coefs.j_eps as f32, &mut eps,
            );
            std::hint::black_box(g);
            solver::apply_step_in_place(&mut x_ip, &eps, &mut x0p, &coefs);
            std::hint::black_box(x_ip[0]);
        }));
    }

    // ---- PJRT paths (need artifacts)
    if let Some(mut be) = runtime::try_load_default() {
        let mut rng = Rng::new(2);
        for &b in &[1usize, 2, 4, 8, 16] {
            let items: Vec<EvalInput> = (0..b)
                .map(|i| EvalInput {
                    x: rng.normal_vec(768),
                    t: 0.5,
                    tokens: vec![1 + (i % 4) as i32, 1, 1, 1],
                })
                .collect();
            be.denoise("dit_b", &items).unwrap(); // warm compile
            let s = bench(&format!("denoiser dit_b bucket {b}"), 3, iters, || {
                std::hint::black_box(be.denoise("dit_b", &items).unwrap());
            });
            println!(
                "bucket {b}: {:.3} ms/batch = {:.3} ms/NFE",
                s.p50_ms,
                s.p50_ms / b as f64
            );
            rows.push(s);
        }
        // device guide vs host combine
        let ec = rng.normal_vec(768);
        let eu = rng.normal_vec(768);
        be.run_guide(&ec, &eu, &[7.5]).unwrap();
        rows.push(bench("device guide exec (b1)", 3, iters, || {
            std::hint::black_box(be.run_guide(&ec, &eu, &[7.5]).unwrap());
        }));
        let x = rng.normal_vec(768);
        let x0p = rng.normal_vec(768);
        let carr = [0.9f32, -0.1, 0.05, 1.2, -0.7];
        be.run_solver(&x, &ec, &x0p, &carr).unwrap();
        rows.push(bench("device solver exec (b1)", 3, iters, || {
            std::hint::black_box(be.run_solver(&x, &ec, &x0p, &carr).unwrap());
        }));
    }

    println!();
    print_summaries(&rows);
    println!(
        "\nreading: the packed engine-loop row is the per-NFE L3 overhead this\n\
         repo optimizes; it should sit below the legacy per-item row. Per-NFE\n\
         cost should fall with bucket size (batching pays); host combine+solve\n\
         should be far below one denoiser NFE (it is the right place for the\n\
         per-step math — the device round-trip dominates the device\n\
         guide/solver numbers)."
    );

    if let Some(path) = args.get("out") {
        write_json(path, &rows, &derived);
    }
}
