//! **Figures 7 / 11** — negative prompts under AG. The capability that
//! guidance distillation loses and AG keeps: the unconditional stream is
//! replaced by a *dynamic* negative prompt. Protocol: prompts asking for
//! white shapes with a negative prompt on a color; measure that color's
//! dominance in the output. AG must match CFG's suppression; the
//! distillation proxy (cond-only) cannot apply the negative at all.
//!
//! Run: `cargo bench --bench fig7_negative -- --n 40`

use adaptive_guidance::coordinator::engine::Engine;
use adaptive_guidance::coordinator::policy::{Ag, Cfg, CondOnly, Policy};
use adaptive_guidance::eval::harness::{mean_std, print_table, run_policy, ssim_series, RunSpec};
use adaptive_guidance::eval::probe::color_dominance;
use adaptive_guidance::prompts::{self, Prompt};
use adaptive_guidance::runtime;
use adaptive_guidance::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let Some(be) = runtime::try_load_default() else { return };
    let img = be.manifest.img;
    let n = args.usize("n", 16);
    let steps = args.usize("steps", 20);
    let s = args.f64("guidance", 7.5) as f32;
    let gamma_bar = args.f64("gamma-bar", 0.9988);
    let model = args.get_or("model", "dit_b").to_owned();

    // prompts: red shapes; negative prompt: "red" (color slot = 1)
    // → guidance must push the output *away* from red.
    let neg_color_slot = 1usize;
    let neg_color = 1i32; // red
    let ps: Vec<Prompt> = prompts::eval_set(n, 42)
        .into_iter()
        .map(|mut p| {
            p.color = 0; // ask for red…
            p
        })
        .collect();

    println!("# Fig. 7 — negative prompts (\"red\" suppressed), model={model}, {n} prompts\n");

    let mut engine = Engine::new(be).expect("engine");
    let mut spec = RunSpec::new(&model, steps);

    // without negative prompt (control: red prompts come out red)
    let control = run_policy(&mut engine, &ps, &spec, Cfg { s }.into_ref()).unwrap();

    spec.neg_tokens = Some(prompts::negative_tokens(neg_color_slot, neg_color));
    let cfg_neg = run_policy(&mut engine, &ps, &spec, Cfg { s }.into_ref()).unwrap();
    let ag_neg = run_policy(&mut engine, &ps, &spec,
                            Ag { s, gamma_bar }.into_ref()).unwrap();
    let gd_neg = run_policy(&mut engine, &ps, &spec, CondOnly.into_ref()).unwrap();

    let red = |run: &adaptive_guidance::eval::harness::PolicyRun| {
        let v: Vec<f64> = run
            .completions
            .iter()
            .map(|c| color_dominance(&c.image, img, img, 0))
            .collect();
        mean_std(&v)
    };
    let (c0, _) = red(&control);
    let rows: Vec<Vec<String>> = [
        ("CFG, no negative (control)", &control),
        ("CFG + negative \"red\"", &cfg_neg),
        (&format!("AG γ̄={gamma_bar} + negative") as &str, &ag_neg),
        ("GD proxy (cannot apply neg.)", &gd_neg),
    ]
    .iter()
    .map(|(name, run)| {
        let (rm, rs) = red(run);
        let (sm, _) = mean_std(&ssim_series(run, &cfg_neg, img));
        vec![
            name.to_string(),
            format!("{:.3}±{:.3}", rm, rs),
            format!("{:.3}", sm),
            format!("{:.1}", run.mean_nfes()),
        ]
    })
    .collect();
    print_table(
        &["policy", "red dominance", "SSIM vs CFG+neg", "NFEs/img"],
        &rows,
    );
    let (cfgneg_red, _) = red(&cfg_neg);
    let (agneg_red, _) = red(&ag_neg);
    let (gd_red, _) = red(&gd_neg);
    println!(
        "\nsuppression vs control ({c0:.3}): CFG {:.0}%, AG {:.0}%, GD-proxy {:.0}% — \
         AG must track CFG; the distilled proxy cannot honor the negative.",
        100.0 * (c0 - cfgneg_red) / c0.abs().max(1e-9),
        100.0 * (c0 - agneg_red) / c0.abs().max(1e-9),
        100.0 * (c0 - gd_red) / c0.abs().max(1e-9)
    );
}
