//! **Connection-scaling bench** (§Scale) — the reactor front end vs the
//! thread-per-connection baseline over real TCP, closed-loop.
//!
//! For each front end (`--net reactor|threads`) and each connection
//! count in `--conns-sweep` (default 32,256,1024), the bench opens that
//! many persistent connections to an in-process `serve_on` fleet, then
//! runs `--rounds` closed-loop rounds: every connection has exactly one
//! id-tagged request in flight, a round completes when every reply has
//! arrived. Requests are tiny (`--steps`, default 4, on a small GMM) so
//! the measured quantity is front-end dispatch overhead — threads,
//! wakeups, reply routing — not denoising time.
//!
//! Reported per row: total requests served, wall seconds, throughput
//! (req/s), and mean per-round latency. The expectation this bench
//! guards: reactor throughput stays flat (or grows) as connections
//! scale to 1024, while the baseline pays per-connection thread costs;
//! both serve byte-identical bytes (`rust/tests/reactor_integration.rs`
//! proves parity — this file only times).
//!
//! Run: `cargo bench --bench conn_scaling -- --conns-sweep 32,256,1024`
//! JSON: `--out conn_scaling.json`, or `--merge-into BENCH_perf.json`
//! to fold the sweep into the shared perf trajectory under
//! `"conn_scaling"` (`scripts/bench.sh` does this).

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use adaptive_guidance::backend::GmmBackend;
use adaptive_guidance::coordinator::spec::PolicyRegistry;
use adaptive_guidance::eval::harness::print_table;
use adaptive_guidance::fleet::Fleet;
use adaptive_guidance::server::{serve_on, NetMode, ServerConfig};
use adaptive_guidance::sim::gmm::Gmm;
use adaptive_guidance::util::cli::Args;
use adaptive_guidance::util::json;

fn spawn_server(net: NetMode) -> SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("local_addr");
    let scfg = ServerConfig {
        model: "gmm".into(),
        addr: addr.to_string(),
        shards: 2,
        workers: 2,
        net,
        ..Default::default()
    };
    let fleet = Arc::new(Fleet::launch(
        |_shard| Ok(GmmBackend::new(Gmm::axes(8, 3, 3.0, 0.05))),
        scfg.fleet_config(),
    ));
    let registry = Arc::new(PolicyRegistry::builtin());
    std::thread::spawn(move || {
        let _ = serve_on(listener, fleet, scfg, registry);
    });
    addr
}

struct Row {
    net: &'static str,
    conns: usize,
    requests: usize,
    secs: f64,
    round_ms: f64,
}

fn drive(net: NetMode, name: &'static str, conns: usize, rounds: usize, steps: usize) -> Row {
    let addr = spawn_server(net);
    let mut socks: Vec<(TcpStream, BufReader<TcpStream>)> = (0..conns)
        .map(|_| {
            let s = TcpStream::connect(addr).expect("connect");
            s.set_read_timeout(Some(Duration::from_secs(60))).ok();
            let r = BufReader::new(s.try_clone().expect("clone"));
            (s, r)
        })
        .collect();
    // one warm-up round outside the timed window (thread spawn, page
    // faults, fleet warm-up), then the measured rounds
    let mut round_times = Vec::with_capacity(rounds);
    for round in 0..rounds + 1 {
        let t0 = Instant::now();
        for (i, (w, _)) in socks.iter_mut().enumerate() {
            writeln!(
                w,
                r#"{{"id": {round}, "prompt": "red circle", "policy": "cfg", "steps": {steps}, "guidance": 2.0, "seed": {i}}}"#
            )
            .expect("write");
        }
        for (_, r) in socks.iter_mut() {
            let mut line = String::new();
            let n = r.read_line(&mut line).expect("read");
            assert!(n > 0, "server closed a connection mid-round");
            assert!(
                !line.contains("\"error\""),
                "bench request refused: {line}"
            );
        }
        if round > 0 {
            round_times.push(t0.elapsed().as_secs_f64());
        }
    }
    let secs: f64 = round_times.iter().sum();
    Row {
        net: name,
        conns,
        requests: conns * rounds,
        secs,
        round_ms: 1000.0 * secs / rounds as f64,
    }
}

fn main() {
    let args = Args::from_env();
    let rounds = args.usize("rounds", 4);
    let steps = args.usize("steps", 4);
    let sweep: Vec<usize> = args
        .get_or("conns-sweep", "32,256,1024")
        .split(',')
        .map(|tok| tok.trim().parse().expect("--conns-sweep: integer list"))
        .collect();

    println!(
        "# Connection scaling — closed-loop, {rounds} rounds, cfg steps={steps}, \
         reactor vs threads\n"
    );

    let mut rows = Vec::new();
    for &conns in &sweep {
        for (net, name) in [(NetMode::Reactor, "reactor"), (NetMode::Threads, "threads")] {
            rows.push(drive(net, name, conns, rounds, steps));
        }
    }

    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.net.to_string(),
                r.conns.to_string(),
                r.requests.to_string(),
                format!("{:.3}", r.secs),
                format!("{:.0}", r.requests as f64 / r.secs.max(1e-9)),
                format!("{:.1}", r.round_ms),
            ]
        })
        .collect();
    print_table(
        &["net", "conns", "requests", "secs", "req/s", "round ms"],
        &table,
    );

    let rows_json = json::arr(
        rows.iter()
            .map(|r| {
                json::obj(vec![
                    ("net", json::s(r.net)),
                    ("conns", json::num(r.conns as f64)),
                    ("requests", json::num(r.requests as f64)),
                    ("secs", json::num(r.secs)),
                    ("rps", json::num(r.requests as f64 / r.secs.max(1e-9))),
                    ("round_ms", json::num(r.round_ms)),
                ])
            })
            .collect(),
    );
    let sweep_obj = json::obj(vec![
        ("rounds", json::num(rounds as f64)),
        ("steps", json::num(steps as f64)),
        ("rows", rows_json),
    ]);

    if let Some(path) = args.get("out") {
        std::fs::write(path, json::to_string(&sweep_obj)).expect("write --out");
        eprintln!("results written to {path}");
    }

    // fold into the shared perf trajectory, same contract as
    // sched_tail_latency: a present-but-unparseable file is a hard error
    // (never clobber a recorded trajectory)
    if let Some(path) = args.get("merge-into") {
        let mut map = match std::fs::read_to_string(path) {
            Ok(text) => match json::parse(&text) {
                Ok(json::Value::Obj(map)) => map,
                Ok(_) | Err(_) => panic!(
                    "--merge-into {path}: existing file is not a JSON object; \
                     refusing to overwrite it (delete it to start fresh)"
                ),
            },
            Err(_) => Default::default(),
        };
        map.insert("conn_scaling".to_owned(), sweep_obj);
        std::fs::write(path, json::to_string(&json::Value::Obj(map)))
            .expect("write --merge-into");
        eprintln!("connection sweep merged into {path}");
    }
}
