//! **Figure 4** — cosine similarity γ_t between conditional and
//! unconditional score predictions over the trajectory: mean and 99% CI
//! across prompts, on both model sizes (LDM-512 → dit_s, EMU-768 → dit_b).
//! The paper's finding: γ_t rises ≈monotonically toward 1, and the trend
//! transfers across model scales.
//!
//! Run: `cargo bench --bench fig4_cosine -- --n 64`

use adaptive_guidance::coordinator::engine::Engine;
use adaptive_guidance::coordinator::policy::{Cfg, Policy};
use adaptive_guidance::eval::harness::{print_table, run_policy, RunSpec};
use adaptive_guidance::prompts;
use adaptive_guidance::runtime;
use adaptive_guidance::stats;
use adaptive_guidance::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let Some(be) = runtime::try_load_default() else { return };
    let n = args.usize("n", 32);
    let steps = args.usize("steps", 20);
    let s = args.f64("guidance", 7.5) as f32;

    println!("# Fig. 4 — γ_t (Eq. 7) over the trajectory, mean [99% CI], {n} prompts\n");

    let ps = prompts::eval_set(n, 42);
    let mut engine = Engine::new(be).expect("engine");
    let mut table: Vec<Vec<String>> = (0..steps)
        .map(|t| vec![format!("{t}")])
        .collect();
    let mut headers: Vec<String> = vec!["step".into()];

    for model in ["dit_s", "dit_b"] {
        let spec = RunSpec::new(model, steps);
        let run = run_policy(&mut engine, &ps, &spec, Cfg { s }.into_ref()).unwrap();
        headers.push(format!("{model} γ(x0) mean [99% CI]"));
        headers.push(format!("{model} γ(ε)"));
        for t in 0..steps {
            let gs: Vec<f64> = run.completions.iter().map(|c| c.gammas[t]).collect();
            let ge: Vec<f64> = run.completions.iter().map(|c| c.gammas_eps[t]).collect();
            let (lo, hi) = stats::mean_ci(&gs, stats::Z_99);
            table[t].push(format!("{:.5} [{:.5}, {:.5}]", stats::mean(&gs), lo, hi));
            table[t].push(format!("{:.5}", stats::mean(&ge)));
        }
        // monotonicity check (paper: "increases almost monotonically")
        let first: f64 = run.completions.iter().map(|c| c.gammas[0]).sum::<f64>()
            / run.completions.len() as f64;
        let last: f64 = run
            .completions
            .iter()
            .map(|c| c.gammas[steps - 1])
            .sum::<f64>()
            / run.completions.len() as f64;
        println!(
            "{model}: γ_first = {first:.6}, γ_last = {last:.6} — {}",
            if last > first {
                "rises toward 1 ✓ (paper's Eq. 7 limit)"
            } else {
                "NOT rising (model quality gates this; see DESIGN.md §3)"
            }
        );
    }
    println!();
    let headers_ref: Vec<&str> = headers.iter().map(String::as_str).collect();
    print_table(&headers_ref, &table);
}
