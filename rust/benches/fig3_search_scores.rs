//! **Figure 3** — NAS search results: softmax scores per guidance option
//! over the diffusion steps, aggregated over several independent searches
//! (the paper shows the 30 best; we default to 4 and report mean±std).
//! The paper's pattern: CFG mass is high early and decays in the second
//! half, where cond/uncond options take over.
//!
//! Also covers the §4.2 search-space claim: most of the final probability
//! mass collapses onto {uncond, cond, cfg(s)} rather than scaled variants.
//!
//! Run: `cargo bench --bench fig3_search_scores -- --searches 4 --iters 40`

use adaptive_guidance::eval::harness::print_table;
use adaptive_guidance::prompts::Prompt;
use adaptive_guidance::runtime;
use adaptive_guidance::search::{run_search, SearchConfig};
use adaptive_guidance::stats;
use adaptive_guidance::util::cli::Args;
use adaptive_guidance::util::rng::Rng;

fn main() {
    let args = Args::from_env();
    let Some(mut be) = runtime::try_load_default() else { return };
    let meta = be.manifest.search.clone();
    if meta.artifact.is_none() {
        eprintln!("search_grad artifact missing (re-run `make artifacts`)");
        return;
    }
    let searches = args.usize("searches", 2);
    let iters = args.usize("iters", 25);
    let latent_len = be.manifest.flat_dim;

    println!(
        "# Fig. 3 — per-step option scores from {} DARTS searches × {} Lion iters",
        searches, iters
    );
    println!("# options: {:?}, costs {:?}, target {}\n",
             meta.options, meta.costs, meta.cost_target);

    let mut all_scores: Vec<Vec<Vec<f64>>> = Vec::new(); // [search][step][option]
    for run_idx in 0..searches {
        let cfg = SearchConfig {
            steps: meta.steps,
            options: meta.options.len(),
            batch: meta.batch,
            latent_len,
            iters,
            lr: args.f64("lr", 0.02) as f32,
            seed: args.u64("seed", 0) + run_idx as u64,
        };
        let mut grad =
            |a: &[f32], g: &[f32], x: &[f32], t: &[i32]| be.run_search_grad(a, g, x, t);
        let res = run_search(&mut grad, &cfg, |rng: &mut Rng| {
            Prompt::nth(rng.below(Prompt::space_size())).tokens()
        })
        .unwrap();
        eprintln!(
            "search {run_idx}: loss {:.5} → {:.5}, soft-NFE {:.1}",
            res.trace.loss[0],
            res.trace.loss.last().unwrap(),
            res.trace.soft_nfe.last().unwrap()
        );
        all_scores.push(res.scores());
    }

    let steps = meta.steps;
    let k = meta.options.len();
    let mut rows = Vec::new();
    for t in 0..steps {
        let mut row = vec![format!("{t}")];
        for o in 0..k {
            let vals: Vec<f64> = all_scores.iter().map(|s| s[t][o]).collect();
            row.push(format!("{:.3}±{:.3}", stats::mean(&vals), stats::std_dev(&vals)));
        }
        rows.push(row);
    }
    let mut headers = vec!["step".to_string()];
    headers.extend(meta.options.iter().cloned());
    let headers_ref: Vec<&str> = headers.iter().map(String::as_str).collect();
    print_table(&headers_ref, &rows);

    // Fig. 3's summary statistic: CFG mass first half vs second half
    let cfg_mass = |range: std::ops::Range<usize>| {
        let mut acc = 0.0;
        let mut cnt = 0.0;
        for s in &all_scores {
            for t in range.clone() {
                acc += s[t][2] + s[t][3] + s[t][4];
                cnt += 1.0;
            }
        }
        acc / cnt
    };
    let early = cfg_mass(0..steps / 2);
    let late = cfg_mass(steps / 2..steps);
    println!(
        "\nCFG option mass: first half {early:.3}, second half {late:.3} — {}",
        if early > late {
            "decays over time ✓ (the paper's Fig. 3 pattern)"
        } else {
            "no decay (increase --iters)"
        }
    );
    // §4.2: mass on the scaled-guidance options
    let scaled: f64 = {
        let mut acc = 0.0;
        let mut cnt = 0.0;
        for s in &all_scores {
            for row in s {
                acc += row[2] + row[4];
                cnt += 1.0;
            }
        }
        acc / cnt
    };
    println!(
        "mass on scaled CFG (s/2, 2s): {scaled:.3} (paper §4.2: best policies \
         collapse onto uncond/cond/cfg(s))"
    );
}
