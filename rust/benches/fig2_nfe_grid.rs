//! **Figure 2** — AG vs CFG at matched NFE budgets: AG keeps all T
//! denoising iterations but raises γ̄ to drop guidance; CFG reduces the total
//! step count. Vertically aligned columns = equal NFEs. The paper's
//! observation: AG replicates the 40-NFE baseline closely while reduced-step
//! CFG introduces artifacts.
//!
//! Run: `cargo bench --bench fig2_nfe_grid -- --n 64 [--model dit_b]`

use adaptive_guidance::coordinator::engine::Engine;
use adaptive_guidance::coordinator::policy::{Ag, Cfg, Policy};
use adaptive_guidance::eval::harness::{mean_std, print_table, run_policy, ssim_series, RunSpec};
use adaptive_guidance::prompts;
use adaptive_guidance::runtime;
use adaptive_guidance::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let Some(be) = runtime::try_load_default() else { return };
    let img = be.manifest.img;
    let n = args.usize("n", 32);
    let steps = args.usize("steps", 20);
    let s = args.f64("guidance", 7.5) as f32;
    let model = args.get_or("model", "dit_b");

    println!("# Fig. 2 — AG (γ̄ sweep, top row) vs CFG (step reduction, bottom row)");
    println!("# model={model}, {n} prompts, baseline T={steps} (40 NFEs)\n");

    let ps = prompts::eval_set(n, 42);
    let spec = RunSpec::new(model, steps);
    let mut engine = Engine::new(be).expect("engine");
    let baseline = run_policy(&mut engine, &ps, &spec, Cfg { s }.into_ref()).unwrap();

    // AG row: sweep γ̄ downward → fewer NFEs (same iteration count)
    let mut rows = Vec::new();
    for &gamma_bar in &[1.0001, 0.99995, 0.9999, 0.9995, 0.999, 0.998, 0.995, 0.99] {
        let run = run_policy(&mut engine, &ps, &spec,
                             Ag { s, gamma_bar }.into_ref()).unwrap();
        let (sm, ss) = mean_std(&ssim_series(&run, &baseline, img));
        rows.push(vec![
            format!("AG γ̄={gamma_bar}"),
            format!("{:.1}", run.mean_nfes()),
            format!("{:.3}±{:.3}", sm, ss),
        ]);
    }
    // CFG row: reduce steps → matched NFE budgets
    for &t in &[20usize, 18, 16, 14, 12, 11] {
        let run = run_policy(&mut engine, &ps, &RunSpec::new(model, t),
                             Cfg { s }.into_ref()).unwrap();
        let (sm, ss) = mean_std(&ssim_series(&run, &baseline, img));
        rows.push(vec![
            format!("CFG T={t}"),
            format!("{:.1}", run.mean_nfes()),
            format!("{:.3}±{:.3}", sm, ss),
        ]);
    }
    print_table(&["policy", "NFEs/img", "SSIM vs 40-NFE baseline"], &rows);
    println!("\nreading: at equal NFEs the AG rows should dominate the CFG rows \
              (the paper's \"AG replicates the baseline, naive reduction does not\").");
}
