//! **Figure 10** — the annotator vote-difference distribution for the
//! AG-vs-CFG study: symmetric around zero ("hence, paired difference tests
//! can find no significant difference").
//!
//! Run: `cargo bench --bench fig10_vote_dist -- --n 200`

use adaptive_guidance::coordinator::engine::Engine;
use adaptive_guidance::coordinator::policy::{Ag, Cfg, Policy};
use adaptive_guidance::eval::annotators::{run_study, Panel};
use adaptive_guidance::eval::harness::{run_policy, RunSpec};
use adaptive_guidance::prompts;
use adaptive_guidance::runtime;
use adaptive_guidance::stats::hist::Histogram;
use adaptive_guidance::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let Some(be) = runtime::try_load_default() else { return };
    let img = be.manifest.img;
    let n = args.usize("n", 64);
    let steps = args.usize("steps", 20);
    let s = args.f64("guidance", 7.5) as f32;
    let gamma_bar = args.f64("gamma-bar", 0.9988);
    let model = args.get_or("model", "dit_b");

    println!("# Fig. 10 — vote-difference distribution (5 simulated annotators, {n} pairs)\n");

    let ps = prompts::eval_set(n, 42);
    let spec = RunSpec::new(model, steps);
    let mut engine = Engine::new(be).expect("engine");
    let cfg = run_policy(&mut engine, &ps, &spec, Cfg { s }.into_ref()).unwrap();
    let ag = run_policy(&mut engine, &ps, &spec, Ag { s, gamma_bar }.into_ref()).unwrap();
    let pairs: Vec<(Vec<f32>, Vec<f32>)> = ag
        .completions
        .iter()
        .zip(&cfg.completions)
        .map(|(a, c)| (a.image.clone(), c.image.clone()))
        .collect();
    let outcome = run_study(&pairs, img, img, &Panel::default(), 7);

    let mut hist = Histogram::new(-5.5, 5.5, 11);
    for &d in &outcome.diffs {
        hist.add(d);
    }
    println!("{}", hist.ascii(40));
    println!(
        "mean {:.3} (SD {:.3});  symmetry: |mean|/SD = {:.3} (paper: -0.047 / 2.543 = 0.018)",
        outcome.mean_diff,
        outcome.sd_diff,
        outcome.mean_diff.abs() / outcome.sd_diff.max(1e-9)
    );
    println!(
        "Wilcoxon p = {:.3} → {}",
        outcome.wilcoxon.p_value,
        if outcome.wilcoxon.p_value > 0.05 {
            "no significant difference ✓"
        } else {
            "significant — unexpected"
        }
    );
}
