//! **Figure 1** (headline) + the latency footnote — the cost axis of the
//! paper's claims on this testbed:
//!   * CFG ≈ 2× the latency/NFEs of a guidance-distilled model (CondOnly),
//!   * AG recovers ~50% of GD's speed-up, training-free,
//!   * AG beats the naive step-reduction at matched NFEs.
//!
//! Run: `cargo bench --bench fig1_headline -- --n 64 --gamma-bar 0.9995`
//!
//! `--extra POLICY` adds one more comparison row, built by name (or inline
//! `{"kind": ..}` JSON) through the PolicySpec registry — any registered
//! policy, including plugins, joins the headline table.

use adaptive_guidance::coordinator::engine::Engine;
use adaptive_guidance::coordinator::policy::{Ag, Cfg, CondOnly, Policy};
use adaptive_guidance::coordinator::spec::{PolicyRegistry, PolicySpec};
use adaptive_guidance::eval::harness::{mean_std, print_table, run_policy, ssim_series, RunSpec};
use adaptive_guidance::prompts;
use adaptive_guidance::runtime;
use adaptive_guidance::util::cli::Args;
use adaptive_guidance::util::json;

fn main() {
    let args = Args::from_env();
    let Some(be) = runtime::try_load_default() else { return };
    let img = be.manifest.img;
    let n = args.usize("n", 32);
    let steps = args.usize("steps", 20);
    let s = args.f64("guidance", 7.5) as f32;
    let gamma_bar = args.f64("gamma-bar", 0.9988);
    let model = args.get_or("model", "dit_b");

    println!("# Fig. 1 — headline comparison (model={model}, {n} prompts, T={steps})\n");

    let ps = prompts::eval_set(n, 42);
    let spec = RunSpec::new(model, steps);
    let mut engine = Engine::new(be).expect("engine");

    let cfg = run_policy(&mut engine, &ps, &spec, Cfg { s }.into_ref()).unwrap();
    let ag = run_policy(&mut engine, &ps, &spec, Ag { s, gamma_bar }.into_ref()).unwrap();
    let gd = run_policy(&mut engine, &ps, &spec, CondOnly.into_ref()).unwrap();
    // naive reduction: CFG with fewer steps so total NFEs ≈ AG's
    let naive_steps = ((ag.mean_nfes() / 2.0).round() as usize).clamp(2, steps);
    let naive_spec = RunSpec::new(model, naive_steps);
    let naive = run_policy(&mut engine, &ps, &naive_spec, Cfg { s }.into_ref()).unwrap();
    // optional extra row: any registered policy, via the PolicySpec registry
    let extra = args.get("extra").map(|text| {
        let mut pspec = PolicySpec::parse(text).expect("--extra policy spec");
        pspec.set_default("s", json::num(s as f64));
        let policy = PolicyRegistry::builtin().build(&pspec).expect("--extra policy");
        let name = policy.name();
        (name, run_policy(&mut engine, &ps, &spec, policy).unwrap())
    });

    let ag_label = format!("AG γ̄={gamma_bar}");
    let naive_label = format!("naive CFG T={naive_steps}");
    let mut named: Vec<(&str, &adaptive_guidance::eval::harness::PolicyRun)> = vec![
        ("CFG (baseline)", &cfg),
        (ag_label.as_str(), &ag),
        ("GD proxy (cond-only)", &gd),
        (naive_label.as_str(), &naive),
    ];
    if let Some((name, run)) = &extra {
        named.push((name.as_str(), run));
    }
    let rows: Vec<Vec<String>> = named
        .iter()
    .map(|(name, run)| {
        let (sm, ss) = mean_std(&ssim_series(run, &cfg, img));
        vec![
            name.to_string(),
            format!("{:.1}±{:.1}", run.mean_nfes(), run.nfe_std()),
            format!("{:.1}", run.wall.as_secs_f64() * 1e3 / n as f64),
            format!("{:.3}±{:.3}", sm, ss),
            format!("{:.1}", run.mean_occupancy),
        ]
    })
    .collect();
    print_table(
        &["policy", "NFEs/img", "ms/img", "SSIM vs CFG", "occupancy"],
        &rows,
    );

    let cfg_ms = cfg.wall.as_secs_f64() / n as f64;
    let ag_ms = ag.wall.as_secs_f64() / n as f64;
    let gd_ms = gd.wall.as_secs_f64() / n as f64;
    println!(
        "\nlatency ratios: CFG/GD = {:.2}x (paper footnote: ~1.85x on A100);  \
         AG/GD = {:.2}x",
        cfg_ms / gd_ms,
        ag_ms / gd_ms
    );
    let gd_speedup = cfg_ms - gd_ms;
    let ag_speedup = cfg_ms - ag_ms;
    println!(
        "AG NFE saving: {:.1}% (paper: 25%);  AG achieves {:.0}% of GD's wall-clock \
         speed-up (paper: ~50%)",
        100.0 * (1.0 - ag.mean_nfes() / cfg.mean_nfes()),
        100.0 * ag_speedup / gd_speedup
    );
}
