//! **Figure 17** — "the denoising process displays scene organization even
//! in early iterations": the point-wise differences between consecutive
//! decoded iterates correlate with the final image long before the iterates
//! themselves do. Numeric rendition of the paper's visual panel.
//!
//! Run: `cargo bench --bench fig17_scene_org -- --n 16 [--dump-images out/]`

use adaptive_guidance::coordinator::engine::Engine;
use adaptive_guidance::coordinator::policy::{Cfg, Policy};
use adaptive_guidance::eval::harness::{print_table, run_policy, RunSpec};
use adaptive_guidance::eval::scene_org;
use adaptive_guidance::prompts;
use adaptive_guidance::runtime;
use adaptive_guidance::stats;
use adaptive_guidance::util::cli::Args;
use adaptive_guidance::util::ppm;

fn main() {
    let args = Args::from_env();
    let Some(be) = runtime::try_load_default() else { return };
    let img = be.manifest.img;
    let n = args.usize("n", 8);
    let steps = args.usize("steps", 20);
    let s = args.f64("guidance", 7.5) as f32;
    let model = args.get_or("model", "dit_b");

    println!("# Fig. 17 — iterate vs iterate-delta correlation with the final image\n");

    let ps = prompts::eval_set(n, 42);
    let mut spec = RunSpec::new(model, steps);
    spec.record_iterates = true;
    let mut engine = Engine::new(be).expect("engine");
    let run = run_policy(&mut engine, &ps, &spec, Cfg { s }.into_ref()).unwrap();

    // aggregate the per-step rows across prompts
    let mut rows = Vec::new();
    let analyses: Vec<Vec<scene_org::SceneOrgRow>> = run
        .completions
        .iter()
        .map(|c| scene_org::analyze(&c.iterates))
        .collect();
    for t in 0..steps - 1 {
        let it_corr: Vec<f64> = analyses.iter().map(|a| a[t].iterate_corr).collect();
        let d_corr: Vec<f64> = analyses.iter().map(|a| a[t].delta_corr).collect();
        let rms: Vec<f64> = analyses.iter().map(|a| a[t].delta_rms).collect();
        rows.push(vec![
            (t + 1).to_string(),
            format!("{:.3}", stats::mean(&rms)),
            format!("{:.3}", stats::mean(&it_corr)),
            format!("{:.3}", stats::mean(&d_corr)),
        ]);
    }
    print_table(
        &["step", "delta RMS", "corr(iterate, final)", "corr(delta, final)"],
        &rows,
    );

    // the paper's claim, quantified: in the first quarter of the process the
    // *delta* correlates with the final image much more than the iterate.
    let early = 0..(steps - 1) / 4;
    let e_it: f64 = stats::mean(
        &analyses
            .iter()
            .flat_map(|a| early.clone().map(|t| a[t].iterate_corr))
            .collect::<Vec<_>>(),
    );
    let e_d: f64 = stats::mean(
        &analyses
            .iter()
            .flat_map(|a| early.clone().map(|t| a[t].delta_corr))
            .collect::<Vec<_>>(),
    );
    println!(
        "\nearly-process (first quarter): corr(iterate, final) = {e_it:.3}, \
         corr(delta, final) = {e_d:.3} — {}",
        if e_d > e_it {
            "deltas reveal scene organization first ✓"
        } else {
            "no early organization signal"
        }
    );

    if let Some(dir) = args.get("dump-images") {
        std::fs::create_dir_all(dir).unwrap();
        let c = &run.completions[0];
        let picks: Vec<&[f32]> = c.iterates.iter().step_by(4).map(|v| v.as_slice()).collect();
        let ups: Vec<Vec<f32>> = picks.iter().map(|p| ppm::upscale(p, img, img, 8)).collect();
        let refs: Vec<&[f32]> = ups.iter().map(|u| u.as_slice()).collect();
        let path = std::path::Path::new(dir).join("iterates.ppm");
        ppm::write_ppm_row(&path, &refs, img * 8, img * 8).unwrap();
        println!("wrote iterate filmstrip to {}", path.display());
    }
}
