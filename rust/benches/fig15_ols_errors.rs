//! **Figure 15 / Appendix C** — per-step OLS errors: MSE between the
//! Eq. 8 linear estimator ε̂(x_t, ∅) and the true unconditional score, on
//! the training trajectories and a held-out test set (paper: 200 train /
//! 100 test paths from a 20-step CFG model).
//!
//! Run: `cargo bench --bench fig15_ols_errors -- --train 200 --test 100`

use adaptive_guidance::coordinator::engine::Engine;
use adaptive_guidance::coordinator::policy::{Cfg, Policy};
use adaptive_guidance::eval::harness::{print_table, run_policy, RunSpec};
use adaptive_guidance::ols;
use adaptive_guidance::prompts;
use adaptive_guidance::runtime;
use adaptive_guidance::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let Some(be) = runtime::try_load_default() else { return };
    let n_train = args.usize("train", 120);
    let n_test = args.usize("test", 60);
    let steps = args.usize("steps", 20);
    let s = args.f64("guidance", 7.5) as f32;
    let model = args.get_or("model", "dit_b").to_owned();

    println!(
        "# Fig. 15 — per-step OLS MSE ({} train / {} test trajectories, model={model})\n",
        n_train, n_test
    );

    let mut engine = Engine::new(be).expect("engine");
    let mut spec = RunSpec::new(&model, steps);
    spec.record_trajectory = true;
    spec.seed_base = 10_000;
    let ps = prompts::eval_set(n_train + n_test, 11);
    eprintln!("generating {} recorded trajectories…", n_train + n_test);
    let run = run_policy(&mut engine, &ps, &spec, Cfg { s }.into_ref()).unwrap();
    let trajs: Vec<_> = run
        .completions
        .into_iter()
        .map(|c| c.trajectory.unwrap())
        .collect();
    let (train, test) = trajs.split_at(n_train);

    let coeffs = ols::fit(train, 1e-4);
    let train_mse = ols::eval_mse(&coeffs, train);
    let test_mse = ols::eval_mse(&coeffs, test);

    let rows: Vec<Vec<String>> = (0..steps)
        .map(|t| {
            vec![
                format!("{t}"),
                format!("{:.6}", train_mse[t]),
                format!("{:.6}", test_mse[t]),
                format!("{:.2}", test_mse[t] / train_mse[t].max(1e-12)),
            ]
        })
        .collect();
    print_table(&["step", "train MSE", "test MSE", "test/train"], &rows);
    let tm: f64 = test_mse.iter().sum::<f64>() / steps as f64;
    println!(
        "\nmean test MSE {tm:.6} — the paper's observation: the estimator is \
         accurate enough to replace unconditional NFEs, and train/test curves \
         overlap (no overfitting despite {} scalar coefficients/step max).",
        2 * steps - 1
    );
}
