//! **Scheduler tail-latency bench** — open-loop arrivals over mixed
//! cfg/ag/linear-ag traffic, comparing p50/p99 latency and occupancy
//! across all four scheduling disciplines at fixed throughput (the same
//! workload, work-conserving engine, and batch capacity for every row).
//!
//! Time is *virtual*: one executed batch = one time unit, since batch
//! execution dominates the serving clock and the GMM oracle's wall time
//! does not. Requests arrive by a Poisson process measured in batches;
//! a request's latency is `completion_batch − arrival_batch`. This makes
//! the bench deterministic (same seed → same numbers) and runnable with no
//! artifacts, while preserving exactly the queueing phenomenon at stake:
//! under FIFO, cheap AG-truncated requests wait behind expensive full-CFG
//! ones; `cost-aware` reorders them and the p99 drops.
//!
//! Run: `cargo bench --bench sched_tail_latency -- --requests 240 --rate 0.5`
//! (`rate` is arrivals per batch; ~0.5 puts the mixed workload near 90%
//! utilisation of the 16-slot bucket — bursty but stable, the regime where
//! queue discipline decides the tail.)
//! JSON: `--out sched_tail_latency.json` writes the table like the other
//! `fig*` benches' `--out` dumps.
//!
//! §Scale: `--shards-sweep 1,2,4` additionally runs the same workload
//! through an N-engine fleet in virtual time — least-loaded placement by
//! live queued NFEs, every non-idle shard pumping one batch per time unit
//! (shards run on parallel threads in the real fleet) — reporting
//! p50/p99 per shard count. `--merge-into BENCH_perf.json` folds the
//! sweep into an existing perf dump under `"sched_shard_sweep"`
//! (`scripts/bench.sh` uses this to keep one perf trajectory file).

use std::collections::HashMap;
use std::sync::Arc;

use adaptive_guidance::backend::GmmBackend;
use adaptive_guidance::coordinator::engine::Engine;
use adaptive_guidance::coordinator::policy::{ag, cfg, linear_ag, PolicyRef};
use adaptive_guidance::coordinator::request::Request;
use adaptive_guidance::eval::harness::print_table;
use adaptive_guidance::ols::OlsCoeffs;
use adaptive_guidance::sched::{Admission, SchedulerKind};
use adaptive_guidance::sim::gmm::Gmm;
use adaptive_guidance::stats;
use adaptive_guidance::util::cli::Args;
use adaptive_guidance::util::json;
use adaptive_guidance::util::rng::Rng;

/// The shared workload: arrival batch + request, identical for every
/// scheduler row (same seeds, same policies, same clients/deadlines).
fn workload(n: usize, rate: f64, steps: usize) -> Vec<(f64, Request)> {
    let mut rng = Rng::new(4242);
    let coeffs = Arc::new(OlsCoeffs::identity(steps));
    let mut t = 0.0;
    (0..n)
        .map(|i| {
            t += rng.exponential(rate);
            // mixed traffic: a third full CFG (expensive), a third AG
            // (truncates early on the oracle → cheap), a third LINEARAG.
            // Deadline slacks are separated by far more than any plausible
            // wall-clock run: the engine anchors them at real arrival time,
            // and within a class anchored keys are non-decreasing in
            // arrival order, so the EDF row stays deterministic — class
            // order by slack tier, arrival order within a class.
            let (policy, client, slack): (PolicyRef, &str, u64) = match i % 3 {
                0 => (cfg(2.0), "bulk-cfg", 3_600_000),
                1 => (ag(2.0, 0.99), "interactive-ag", 50),
                _ => (linear_ag(2.0, coeffs.clone()), "batch-linear", 600_000),
            };
            let mut r = Request::new(
                i as u64,
                "gmm",
                vec![1 + (i % 6) as i32, 0, 0, 0],
                9000 + i as u64,
                steps,
                policy,
            );
            r.client_id = Some(Arc::from(client));
            // arrival-relative, like the wire field: interactive requests
            // get a tight budget, bulk a loose one
            r.deadline_ms = Some(slack);
            (t, r)
        })
        .collect()
}

struct Row {
    name: &'static str,
    p50: f64,
    p99: f64,
    mean: f64,
    batches: usize,
    items: usize,
    occupancy: f64,
}

/// Drive the shared workload through one scheduler in virtual time.
fn drive(kind: SchedulerKind, arrivals: &[(f64, Request)]) -> Row {
    let be = GmmBackend::new(Gmm::axes(8, 6, 3.0, 0.05));
    let mut engine = Engine::with_scheduler(be, kind.build(), Admission::unlimited())
        .expect("engine over the GMM oracle");
    let mut submit_batch: HashMap<u64, usize> = HashMap::new();
    let mut latencies: Vec<f64> = Vec::with_capacity(arrivals.len());
    let mut batches = 0usize;
    let mut next = 0;
    while next < arrivals.len() || !engine.idle() {
        while next < arrivals.len() && arrivals[next].0 <= batches as f64 {
            let (_, req) = &arrivals[next];
            submit_batch.insert(req.id, batches);
            engine.submit(req.clone());
            next += 1;
        }
        if engine.idle() {
            // idle with the next arrival in the future: fast-forward
            batches = arrivals[next].0.ceil().max((batches + 1) as f64) as usize;
            continue;
        }
        let done = engine.pump().expect("pump");
        batches += 1;
        for c in done {
            let submitted = submit_batch.remove(&c.id).expect("submitted");
            latencies.push((batches - submitted) as f64);
        }
    }
    Row {
        name: kind.name(),
        p50: stats::percentile(&latencies, 50.0),
        p99: stats::percentile(&latencies, 99.0),
        mean: stats::mean(&latencies),
        batches: engine.batches(),
        items: engine.items(),
        occupancy: engine.mean_occupancy(),
    }
}

/// Drive the shared workload through an N-shard fleet in virtual time:
/// arrivals place least-loaded (live queued NFEs, ties by index — the
/// fleet router's default), and one time unit pumps every non-idle shard
/// once, because real shards are parallel threads. Latency is
/// `completion_round − arrival_round`.
fn drive_shards(shards: usize, arrivals: &[(f64, Request)]) -> Row {
    let mut engines: Vec<Engine<GmmBackend>> = (0..shards)
        .map(|_| {
            Engine::new(GmmBackend::new(Gmm::axes(8, 6, 3.0, 0.05)))
                .expect("engine over the GMM oracle")
        })
        .collect();
    let mut submit_round: HashMap<u64, usize> = HashMap::new();
    let mut latencies: Vec<f64> = Vec::with_capacity(arrivals.len());
    let mut rounds = 0usize;
    let mut next = 0;
    while next < arrivals.len() || engines.iter().any(|e| !e.idle()) {
        while next < arrivals.len() && arrivals[next].0 <= rounds as f64 {
            let (_, req) = &arrivals[next];
            let target = (0..shards)
                .min_by_key(|&i| (engines[i].queued_nfes(), i))
                .expect("at least one shard");
            submit_round.insert(req.id, rounds);
            engines[target].submit(req.clone());
            next += 1;
        }
        if engines.iter().all(|e| e.idle()) {
            // idle with the next arrival in the future: fast-forward
            rounds = arrivals[next].0.ceil().max((rounds + 1) as f64) as usize;
            continue;
        }
        let mut done = Vec::new();
        for e in engines.iter_mut() {
            if !e.idle() {
                done.extend(e.pump().expect("pump"));
            }
        }
        rounds += 1;
        for c in done {
            let submitted = submit_round.remove(&c.id).expect("submitted");
            latencies.push((rounds - submitted) as f64);
        }
    }
    let (batches, items): (usize, usize) = engines
        .iter()
        .fold((0, 0), |(b, i), e| (b + e.batches(), i + e.items()));
    Row {
        name: "least-loaded",
        p50: stats::percentile(&latencies, 50.0),
        p99: stats::percentile(&latencies, 99.0),
        mean: stats::mean(&latencies),
        batches,
        items,
        occupancy: if batches == 0 { 0.0 } else { items as f64 / batches as f64 },
    }
}

fn main() {
    let args = Args::from_env();
    let n = args.usize("requests", 240);
    let rate = args.f64("rate", 0.5); // arrivals per executed batch
    let steps = args.usize("steps", 20);

    println!(
        "# Scheduler tail latency — {n} mixed cfg/ag/linear-ag requests, \
         Poisson rate {rate}/batch, T={steps} (latency in batches)\n"
    );

    let arrivals = workload(n, rate, steps);
    let rows: Vec<Row> = SchedulerKind::ALL
        .into_iter()
        .map(|kind| drive(kind, &arrivals))
        .collect();

    // fixed throughput across rows: the engine is work-conserving, so
    // every scheduler executes the same items (batch counts may differ
    // slightly with packing).
    let items = rows[0].items;
    assert!(
        rows.iter().all(|r| r.items == items),
        "schedulers must execute identical work"
    );

    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.name.to_string(),
                format!("{:.1}", r.p50),
                format!("{:.1}", r.p99),
                format!("{:.1}", r.mean),
                r.batches.to_string(),
                format!("{:.1}", r.occupancy),
            ]
        })
        .collect();
    print_table(
        &["scheduler", "p50 (batches)", "p99 (batches)", "mean", "batches", "occupancy"],
        &table,
    );

    let row = |name: &str| rows.iter().find(|r| r.name == name).expect("scheduler row");
    let fifo = row("fifo");
    let cost = row("cost-aware");
    println!(
        "\ncost-aware vs fifo: p99 {:.1} → {:.1} ({:+.1}%), p50 {:.1} → {:.1} \
         (same {items} items executed)",
        fifo.p99,
        cost.p99,
        100.0 * (cost.p99 - fifo.p99) / fifo.p99.max(1e-9),
        fifo.p50,
        cost.p50,
    );
    println!(
        "reading: FIFO queues cheap AG-truncated requests behind full-CFG \
         ones; SRPT-style cost-aware scheduling should cut the tail without \
         changing any request's output."
    );

    // §Scale: the shard-scaling sweep — same workload, N-engine fleet
    let sweep: Vec<(usize, Row)> = match args.get("shards-sweep") {
        None => Vec::new(),
        Some(list) => list
            .split(',')
            .map(|tok| {
                let shards: usize = tok
                    .trim()
                    .parse()
                    .unwrap_or_else(|_| panic!("--shards-sweep: bad count `{tok}`"));
                (shards, drive_shards(shards.max(1), &arrivals))
            })
            .collect(),
    };
    if !sweep.is_empty() {
        println!("\n# Shard scaling (least-loaded placement, fifo shards)\n");
        let table: Vec<Vec<String>> = sweep
            .iter()
            .map(|(shards, r)| {
                vec![
                    shards.to_string(),
                    format!("{:.1}", r.p50),
                    format!("{:.1}", r.p99),
                    format!("{:.1}", r.mean),
                    r.batches.to_string(),
                    format!("{:.1}", r.occupancy),
                ]
            })
            .collect();
        print_table(
            &["shards", "p50 (rounds)", "p99 (rounds)", "mean", "batches", "occupancy"],
            &table,
        );
        // work conservation across topologies: sharding moves work, it
        // never changes it
        assert!(
            sweep.iter().all(|(_, r)| r.items == items),
            "shard counts must execute identical work"
        );
        println!(
            "\nreading: more shards drain the same backlog in fewer rounds — \
             placement spreads batches, results stay byte-identical \
             (rust/tests/fleet_integration.rs pins that)."
        );
    }

    let sweep_json = |sweep: &[(usize, Row)]| {
        json::arr(
            sweep
                .iter()
                .map(|(shards, r)| {
                    json::obj(vec![
                        ("shards", json::num(*shards as f64)),
                        ("p50", json::num(r.p50)),
                        ("p99", json::num(r.p99)),
                        ("mean", json::num(r.mean)),
                        ("batches", json::num(r.batches as f64)),
                        ("items", json::num(r.items as f64)),
                        ("occupancy", json::num(r.occupancy)),
                    ])
                })
                .collect(),
        )
    };

    if let Some(path) = args.get("out") {
        let mut fields = vec![
            ("requests", json::num(n as f64)),
            ("rate", json::num(rate)),
            ("steps", json::num(steps as f64)),
            (
                "schedulers",
                json::arr(
                    rows.iter()
                        .map(|r| {
                            json::obj(vec![
                                ("name", json::s(r.name)),
                                ("p50", json::num(r.p50)),
                                ("p99", json::num(r.p99)),
                                ("mean", json::num(r.mean)),
                                ("batches", json::num(r.batches as f64)),
                                ("items", json::num(r.items as f64)),
                                ("occupancy", json::num(r.occupancy)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ];
        if !sweep.is_empty() {
            fields.push(("shard_sweep", sweep_json(&sweep)));
        }
        let v = json::obj(fields);
        std::fs::write(path, json::to_string(&v)).expect("write --out");
        eprintln!("results written to {path}");
    }

    // fold the sweep into an existing perf dump (scripts/bench.sh keeps
    // one BENCH_perf.json trajectory file). Destroying the existing
    // trajectory is worse than failing: a present-but-unparseable file is
    // a hard error, and an empty sweep never overwrites a recorded one.
    if let Some(path) = args.get("merge-into") {
        if sweep.is_empty() {
            eprintln!("--merge-into {path}: nothing to merge (pass --shards-sweep 1,2,4)");
            return;
        }
        let mut map = match std::fs::read_to_string(path) {
            Ok(text) => match json::parse(&text) {
                Ok(json::Value::Obj(map)) => map,
                Ok(_) | Err(_) => panic!(
                    "--merge-into {path}: existing file is not a JSON object; \
                     refusing to overwrite it (delete it to start fresh)"
                ),
            },
            // no file yet: start a fresh object
            Err(_) => Default::default(),
        };
        map.insert(
            "sched_shard_sweep".to_owned(),
            json::obj(vec![
                ("requests", json::num(n as f64)),
                ("rate", json::num(rate)),
                ("steps", json::num(steps as f64)),
                ("rows", sweep_json(&sweep)),
            ]),
        );
        std::fs::write(path, json::to_string(&json::Value::Obj(map)))
            .expect("write --merge-into");
        eprintln!("shard sweep merged into {path}");
    }
}
