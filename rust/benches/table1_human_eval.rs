//! **Table 1** — AG (γ̄, ~25% fewer NFEs) vs the 20-step CFG baseline:
//! mean SSIM, simulated 5-annotator majority votes, and the two-sided
//! Wilcoxon signed-rank test on vote differences.
//!
//! Paper row (EMU-768, 1000 OUI prompts):
//!   CFG  SSIM 0.91±0.03  win 502  lose 498  NFEs 40
//!   AG   (γ̄=0.991)       win 498  lose 502  NFEs 29.6±1.3
//!
//! Run: `cargo bench --bench table1_human_eval -- --n 200 --gamma-bar 0.9995
//!       [--model dit_b] [--dump-images out/]`

use adaptive_guidance::coordinator::engine::Engine;
use adaptive_guidance::coordinator::policy::{Ag, Cfg, Policy};
use adaptive_guidance::eval::annotators::{run_study, Panel};
use adaptive_guidance::eval::harness::{mean_std, print_table, run_policy, ssim_series, RunSpec};
use adaptive_guidance::prompts;
use adaptive_guidance::runtime;
use adaptive_guidance::util::cli::Args;
use adaptive_guidance::util::ppm;

fn main() {
    let args = Args::from_env();
    let Some(be) = runtime::try_load_default() else { return };
    let img = be.manifest.img;
    let n = args.usize("n", 48);
    let steps = args.usize("steps", 20);
    let s = args.f64("guidance", 7.5) as f32;
    let gamma_bar = args.f64("gamma-bar", 0.9988);
    let model = args.get_or("model", "dit_b");

    println!("# Table 1 — human-evaluation protocol (simulated panel)");
    println!("# model={model} prompts={n} (paper: 1000) steps={steps} γ̄={gamma_bar}\n");

    let ps = prompts::eval_set(n, 42);
    let spec = RunSpec::new(model, steps);
    let mut engine = Engine::new(be).expect("engine");
    let cfg = run_policy(&mut engine, &ps, &spec, Cfg { s }.into_ref()).unwrap();
    let ag = run_policy(&mut engine, &ps, &spec, Ag { s, gamma_bar }.into_ref()).unwrap();

    let ssim = ssim_series(&ag, &cfg, img);
    let (ssim_m, ssim_s) = mean_std(&ssim);

    // the annotator pairs: A = AG image, B = CFG image (paper order: CFG first)
    let pairs: Vec<(Vec<f32>, Vec<f32>)> = cfg
        .completions
        .iter()
        .zip(&ag.completions)
        .map(|(c, a)| (c.image.clone(), a.image.clone()))
        .collect();
    let outcome = run_study(&pairs, img, img, &Panel::default(), 7);

    print_table(
        &["policy", "SSIM(vs CFG)", "win", "lose", "NFEs"],
        &[
            vec![
                "CFG".into(),
                format!("{:.2}±{:.2}", ssim_m, ssim_s),
                outcome.wins_a.to_string(),
                outcome.wins_b.to_string(),
                cfg.mean_nfes().to_string(),
            ],
            vec![
                format!("AG γ̄={gamma_bar}"),
                String::from("—"),
                outcome.wins_b.to_string(),
                outcome.wins_a.to_string(),
                format!("{:.1}±{:.1}", ag.mean_nfes(), ag.nfe_std()),
            ],
        ],
    );
    println!(
        "\nvote diff: mean {:.3} (SD {:.3});  Wilcoxon W={:.0}, z={:.3}, p={:.3} \
         (paper: mean -0.047, SD 2.543, p=0.603)",
        outcome.mean_diff,
        outcome.sd_diff,
        outcome.wilcoxon.w_plus.min(outcome.wilcoxon.w_minus),
        outcome.wilcoxon.z,
        outcome.wilcoxon.p_value
    );
    println!(
        "NFE saving: {:.1}% (paper: ~25%);  significant difference: {}",
        100.0 * (1.0 - ag.mean_nfes() / cfg.mean_nfes()),
        if outcome.wilcoxon.p_value > 0.05 { "no (p > 0.05) ✓" } else { "YES — unexpected" }
    );

    if let Some(dir) = args.get("dump-images") {
        std::fs::create_dir_all(dir).unwrap();
        // dump the most extreme vote differences (Figs. 6/12/13 protocol)
        let mut idx: Vec<usize> = (0..pairs.len()).collect();
        idx.sort_by(|&a, &b| outcome.diffs[b].abs().partial_cmp(&outcome.diffs[a].abs()).unwrap());
        for &i in idx.iter().take(6) {
            let up_cfg = ppm::upscale(&pairs[i].0, img, img, 8);
            let up_ag = ppm::upscale(&pairs[i].1, img, img, 8);
            let path = std::path::Path::new(dir).join(format!(
                "pair_{:03}_diff{}.ppm",
                i, outcome.diffs[i] as i32
            ));
            ppm::write_ppm_row(&path, &[&up_cfg, &up_ag], img * 8, img * 8).unwrap();
        }
        println!("wrote 6 extreme pairs (CFG|AG) to {dir}/");
    }
}
