//! **Figures 8 / 16** — replacing CFG in the *first* half of the
//! trajectory. Three matched-budget strategies (~25 NFEs at T=20):
//!   * AG with very low γ̄ (5 CFG steps + 15 conditional),
//!   * naive alternation (CFG/cond alternating in the first half),
//!   * LINEARAG (Eq. 11: CFG alternating with OLS-estimated CFG, then LR).
//! The paper: LINEARAG recovers most of the quality the others lose, with
//! sharper/higher-contrast outputs.
//!
//! Run: `cargo bench --bench fig8_linearag -- --n 48 --train 160`

use std::sync::Arc;

use adaptive_guidance::coordinator::engine::Engine;
use adaptive_guidance::coordinator::policy::{AgFixedPrefix, AlternatingCfg, Cfg, LinearAg, Policy};
use adaptive_guidance::eval::harness::{mean_std, print_table, run_policy, ssim_series, RunSpec};
use adaptive_guidance::ols;
use adaptive_guidance::prompts;
use adaptive_guidance::quality::high_freq_energy;
use adaptive_guidance::runtime;
use adaptive_guidance::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let Some(be) = runtime::try_load_default() else { return };
    let img = be.manifest.img;
    let n = args.usize("n", 24);
    let n_train = args.usize("train", 96);
    let steps = args.usize("steps", 20);
    let s = args.f64("guidance", 7.5) as f32;
    let model = args.get_or("model", "dit_b").to_owned();

    println!("# Fig. 8 — first-half guidance replacement (model={model}, {n} prompts)\n");

    let mut engine = Engine::new(be).expect("engine");

    // 1) fit OLS on recorded CFG trajectories (App. C: 200 paths, <20 min)
    eprintln!("collecting {n_train} training trajectories for OLS…");
    let mut train_spec = RunSpec::new(&model, steps);
    train_spec.seed_base = 50_000;
    train_spec.record_trajectory = true;
    let train_ps = prompts::eval_set(n_train, 7);
    let train_run = run_policy(&mut engine, &train_ps, &train_spec,
                               Cfg { s }.into_ref()).unwrap();
    let trajs: Vec<_> = train_run
        .completions
        .into_iter()
        .map(|c| c.trajectory.unwrap())
        .collect();
    let coeffs = Arc::new(ols::fit(&trajs, 1e-4));

    // 2) evaluate the three strategies against the full-CFG baseline
    let ps = prompts::eval_set(n, 42);
    let spec = RunSpec::new(&model, steps);
    let baseline = run_policy(&mut engine, &ps, &spec, Cfg { s }.into_ref()).unwrap();
    let base_hf: Vec<f64> = baseline
        .completions
        .iter()
        .map(|c| high_freq_energy(&c.image, img, img))
        .collect();
    let (bh, _) = mean_std(&base_hf);

    let policies = vec![
        ("AG low γ̄ (5 CFG + 15 cond)",
         AgFixedPrefix { s, cfg_steps: 5 }.into_ref()),
        ("alternating CFG/cond",
         AlternatingCfg { s }.into_ref()),
        ("LINEARAG (Eq. 11)",
         LinearAg { s, coeffs: coeffs.clone() }.into_ref()),
    ];
    let mut rows = Vec::new();
    for (name, policy) in policies {
        let run = run_policy(&mut engine, &ps, &spec, policy).unwrap();
        let (sm, ss) = mean_std(&ssim_series(&run, &baseline, img));
        let hf: Vec<f64> = run
            .completions
            .iter()
            .map(|c| high_freq_energy(&c.image, img, img))
            .collect();
        let (hm, _) = mean_std(&hf);
        rows.push(vec![
            name.to_string(),
            format!("{:.1}", run.mean_nfes()),
            format!("{:.3}±{:.3}", sm, ss),
            format!("{:.2}", hm / bh),
        ]);
    }
    print_table(
        &["strategy", "NFEs/img", "SSIM vs CFG", "sharpness ratio"],
        &rows,
    );
    println!("\nreading: LINEARAG should achieve the highest SSIM of the three \
              matched-budget strategies and a sharpness ratio ≥ the others \
              (paper: \"increased sharpness … more vivid colors\").");
}
