//! **Figures 5 and 9** — the SSIM-vs-NFE frontier: AG (γ̄ sweep, "dashed
//! line"), naive CFG step reduction ("solid line"), plus fixed-prefix
//! policies standing in for individual searched policies (dots). Fig. 5 is
//! the LDM-512 analogue (`--model dit_s`, default); Fig. 9 is EMU-768
//! (`--model dit_b`).
//!
//! Run: `cargo bench --bench fig5_frontier -- --model dit_s --n 64`

use adaptive_guidance::coordinator::engine::Engine;
use adaptive_guidance::coordinator::policy::{Ag, AgFixedPrefix, Cfg, Policy};
use adaptive_guidance::eval::harness::{mean_std, print_table, run_policy, ssim_series, RunSpec};
use adaptive_guidance::prompts;
use adaptive_guidance::runtime;
use adaptive_guidance::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let Some(be) = runtime::try_load_default() else { return };
    let img = be.manifest.img;
    let n = args.usize("n", 32);
    let steps = args.usize("steps", 20);
    let s = args.f64("guidance", 7.5) as f32;
    let model = args.get_or("model", "dit_s").to_owned();
    let fig = if model == "dit_s" { "Fig. 5 (LDM analogue)" } else { "Fig. 9 (EMU analogue)" };

    println!("# {fig} — SSIM-vs-NFE frontier, model={model}, {n} prompts\n");

    let ps = prompts::eval_set(n, 42);
    let spec = RunSpec::new(&model, steps);
    let mut engine = Engine::new(be).expect("engine");
    let baseline = run_policy(&mut engine, &ps, &spec, Cfg { s }.into_ref()).unwrap();

    let mut rows = Vec::new();
    let mut eval = |series: &str, name: String, run: &adaptive_guidance::eval::harness::PolicyRun| {
        let (sm, ss) = mean_std(&ssim_series(run, &baseline, img));
        rows.push(vec![
            series.to_string(),
            name,
            format!("{:.1}", run.mean_nfes()),
            format!("{:.3}±{:.3}", sm, ss),
        ]);
    };

    for &gamma_bar in &[0.99995, 0.9999, 0.9995, 0.999, 0.998, 0.995, 0.99, 0.98] {
        let run = run_policy(&mut engine, &ps, &spec,
                             Ag { s, gamma_bar }.into_ref()).unwrap();
        eval("AG (dashed)", format!("γ̄={gamma_bar}"), &run);
    }
    for &t in &[20usize, 18, 16, 14, 12, 11] {
        let run = run_policy(&mut engine, &ps, &RunSpec::new(&model, t),
                             Cfg { s }.into_ref()).unwrap();
        eval("CFG (solid)", format!("T={t}"), &run);
    }
    // "searched policy" dots: deterministic prefix policies of varying budget
    for &k in &[16usize, 12, 10, 8, 6, 4] {
        let run = run_policy(&mut engine, &ps, &spec,
                             AgFixedPrefix { s, cfg_steps: k }.into_ref()).unwrap();
        eval("policy (dot)", format!("prefix k={k}"), &run);
    }
    print_table(&["series", "point", "NFEs/img", "SSIM vs baseline"], &rows);
    println!("\nreading: at matched NFEs the AG series should sit above the CFG \
              series across the whole 22–40 NFE regime (paper: \"strictly better\").");
}
