//! **Figure 14 / Appendix B** — instruction-based image editing with the
//! Eq. 9 triple-evaluation guidance (InstructPix2Pix-style): AG truncates
//! the two auxiliary streams once the text-guidance pair converges, saving
//! ~33% of NFEs at equal quality. Guidance distillation cannot serve this
//! task at all (the "unconditional" stream is dynamic — it contains I).
//!
//! Run: `cargo bench --bench fig14_editing -- --n 32`

use adaptive_guidance::coordinator::engine::Engine;
use adaptive_guidance::coordinator::policy::{Pix2Pix, Policy, PolicyRef};
use adaptive_guidance::coordinator::request::Request;
use adaptive_guidance::eval::harness::{mean_std, print_table};
use adaptive_guidance::eval::probe::color_dominance;
use adaptive_guidance::prompts::Prompt;
use adaptive_guidance::quality::ssim::ssim_rgb;
use adaptive_guidance::render;
use adaptive_guidance::runtime;
use adaptive_guidance::util::cli::Args;
use adaptive_guidance::util::rng::Rng;

fn main() {
    let args = Args::from_env();
    let Some(be) = runtime::try_load_default() else { return };
    if !be.manifest.models.contains_key("dit_edit") {
        eprintln!("dit_edit model missing from artifacts");
        return;
    }
    let img = be.manifest.img;
    let n = args.usize("n", 12);
    let steps = args.usize("steps", 20);
    let s_text = args.f64("s-text", 7.5) as f32;
    let s_img = args.f64("s-img", 1.5) as f32;
    let gamma_bar = args.f64("gamma-bar", 0.9988);

    println!("# Fig. 14 — editing with Eq. 9 guidance: CFG-edit vs AG-edit ({n} edits)\n");

    // synthesize edit tasks: recolor a rendered shape ("make it <color>")
    let mut rng = Rng::new(9);
    let mut cases = Vec::new();
    for i in 0..n {
        let src_prompt = Prompt::nth(rng.below(Prompt::space_size()));
        let mut new_color = rng.below(5);
        if new_color == src_prompt.color {
            new_color = (new_color + 1) % 5;
        }
        let instr = vec![0i32, new_color as i32 + 1, 0, 0];
        cases.push((i as u64, render::render(&src_prompt), instr, new_color));
    }

    let mut engine = Engine::new(be).expect("engine");
    let run = |engine: &mut Engine<_>, policy: PolicyRef| {
        let reqs: Vec<Request> = cases
            .iter()
            .map(|(id, src, instr, _)| {
                let mut r = Request::new(*id, "dit_edit", instr.clone(), 3000 + id,
                                         steps, policy.clone());
                r.src_image = Some(src.clone());
                r
            })
            .collect();
        let t0 = std::time::Instant::now();
        let out = engine.run(reqs).unwrap();
        (out, t0.elapsed())
    };

    let (full, full_wall) = run(&mut engine, Pix2Pix {
        s_text,
        s_img,
        gamma_bar: None,
        full_prefix: None,
    }.into_ref());
    // App. B protocol: AG-edit uses the full Eq. 9 triple-eval for the
    // first T/2 steps, then the (c, I) stream only → 33.3% NFE saving.
    let (ag, ag_wall) = run(&mut engine, Pix2Pix {
        s_text,
        s_img,
        gamma_bar: Some(gamma_bar),
        full_prefix: Some(steps / 2),
    }.into_ref());

    // metrics: NFEs, SSIM(AG-edit, CFG-edit), edit success = new-color dominance
    let ssim: Vec<f64> = full
        .iter()
        .zip(&ag)
        .map(|(a, b)| ssim_rgb(&a.image, &b.image, img, img))
        .collect();
    let success = |outs: &[adaptive_guidance::Completion]| {
        let v: Vec<f64> = outs
            .iter()
            .zip(&cases)
            .map(|(c, (_, _, _, new_color))| {
                // the three rendered primaries map to channels; white/yellow
                // checked via their dominant channels
                let ch = match new_color {
                    0 => 0, // red
                    1 => 1, // green
                    2 => 2, // blue
                    3 => 0, // yellow → red+green; use red channel
                    _ => 0, // white — dominance undefined; red as proxy
                };
                color_dominance(&c.image, img, img, ch)
            })
            .collect();
        mean_std(&v).0
    };
    let nfes = |outs: &[adaptive_guidance::Completion]| {
        outs.iter().map(|c| c.nfes).sum::<usize>() as f64 / outs.len() as f64
    };
    let (sm, ss) = mean_std(&ssim);
    print_table(
        &["policy", "NFEs/edit", "ms/edit", "edit-color dominance"],
        &[
            vec![
                "CFG editing (Eq. 9)".into(),
                format!("{:.1}", nfes(&full)),
                format!("{:.1}", full_wall.as_secs_f64() * 1e3 / n as f64),
                format!("{:.3}", success(&full)),
            ],
            vec![
                format!("AG editing γ̄={gamma_bar}"),
                format!("{:.1}", nfes(&ag)),
                format!("{:.1}", ag_wall.as_secs_f64() * 1e3 / n as f64),
                format!("{:.3}", success(&ag)),
            ],
        ],
    );
    println!(
        "\nAG-edit SSIM vs CFG-edit: {:.3}±{:.3};  NFE saving {:.1}% (paper: 33.3%)",
        sm,
        ss,
        100.0 * (1.0 - nfes(&ag) / nfes(&full))
    );
}
