//! Negative prompts under Adaptive Guidance (paper Fig. 7): the negative
//! prompt rides in the *unconditional* stream, so it is exactly the
//! capability guidance distillation bakes away — and AG keeps.
//!
//! ```sh
//! cargo run --release --example negative_prompts
//! ```

use adaptive_guidance::coordinator::engine::Engine;
use adaptive_guidance::coordinator::policy::{ag, cfg};
use adaptive_guidance::coordinator::request::Request;
use adaptive_guidance::eval::probe::color_dominance;
use adaptive_guidance::prompts::{self, Prompt};
use adaptive_guidance::runtime;
use adaptive_guidance::util::ppm;

fn main() -> anyhow::Result<()> {
    let Some(be) = runtime::try_load_default() else { return Ok(()) };
    let img = be.manifest.img;
    let mut engine = Engine::new(be)?;

    let prompt = Prompt::parse("a large red square at the center").unwrap();
    let neg = prompts::negative_tokens(1, 1); // negative: "red"
    println!("prompt: \"{}\"; negative prompt: \"red\"\n", prompt.text());

    let mk = |id, policy, with_neg: bool| {
        let mut r = Request::new(id, "dit_b", prompt.tokens(), 21, 20, policy);
        if with_neg {
            r.neg_tokens = Some(neg.clone());
        }
        r
    };
    let out = engine.run(vec![
        mk(0, cfg(7.5), false),
        mk(1, cfg(7.5), true),
        mk(2, ag(7.5, 0.9988), true),
    ])?;

    std::fs::create_dir_all("out")?;
    let names = ["cfg_plain", "cfg_negative", "ag_negative"];
    for (c, name) in out.iter().zip(names) {
        let up = ppm::upscale(&c.image, img, img, 8);
        ppm::write_ppm(
            std::path::Path::new(&format!("out/neg_{name}.ppm")),
            &up,
            img * 8,
            img * 8,
        )?;
        println!(
            "{name:>13}: red dominance {:>6.3}, {} NFEs{}",
            color_dominance(&c.image, img, img, 0),
            c.nfes,
            c.truncated_at
                .map(|t| format!(", truncated at step {t}"))
                .unwrap_or_default()
        );
    }
    println!(
        "\nexpected: the negative prompt suppresses red vs the plain run, and \
         AG matches CFG's suppression at fewer NFEs (images in out/)."
    );
    Ok(())
}
