//! **End-to-end serving driver** (the required E2E validation): load the
//! trained model artifacts and serve batched generation requests under an
//! open-loop Poisson arrival process, reporting latency percentiles,
//! throughput, NFE totals, and batch occupancy — once per traffic policy on
//! the same workload.
//!
//! Traffic policies are built by name through the `PolicySpec` registry, so
//! any registered policy (including plugins) can be load-tested:
//!
//! ```sh
//! cargo run --release --example serve_throughput -- --requests 48 --rate 4 \
//!     --policies cfg,ag,cond,compressed-cfg --scheduler cost-aware
//! ```
//!
//! `--scheduler fifo|cost-aware|deadline|fair-share` selects the engine's
//! scheduling discipline (see `rust/benches/sched_tail_latency.rs` for the
//! controlled comparison).

use std::time::{Duration, Instant};

use adaptive_guidance::coordinator::engine::Engine;
use adaptive_guidance::coordinator::policy::PolicyRef;
use adaptive_guidance::coordinator::request::Request;
use adaptive_guidance::coordinator::spec::{PolicyRegistry, PolicySpec};
use adaptive_guidance::eval::harness::print_table;
use adaptive_guidance::metrics::{LatencyRecorder, Throughput};
use adaptive_guidance::prompts;
use adaptive_guidance::runtime;
use adaptive_guidance::sched::{Admission, SchedulerKind};
use adaptive_guidance::util::cli::Args;
use adaptive_guidance::util::json;
use adaptive_guidance::util::rng::Rng;

struct LoadResult {
    name: String,
    lat: LatencyRecorder,
    wall: Duration,
    completed: usize,
    nfes: usize,
    occupancy: f64,
}

fn drive(policy: PolicyRef, name: &str, requests: usize, rate: f64,
         steps: usize, model: &str, scheduler: SchedulerKind) -> Option<LoadResult> {
    // fresh backend per run so executable caches/compile time don't leak
    let mut be = runtime::try_load_default()?;
    be.warmup(model).ok()?;
    let mut engine =
        Engine::with_scheduler(be, scheduler.build(), Admission::unlimited()).ok()?;

    // Poisson arrivals, same seed for every policy → identical workload
    let mut rng = Rng::new(4242);
    let ps = prompts::eval_set(requests, 4242);
    let mut arrivals: Vec<(f64, Request)> = Vec::new();
    let mut t = 0.0;
    for (i, p) in ps.iter().enumerate() {
        t += rng.exponential(rate);
        arrivals.push((
            t,
            Request::new(i as u64, model, p.tokens(), 9000 + i as u64, steps,
                         policy.clone()),
        ));
    }

    let mut lat = LatencyRecorder::new();
    let mut thr = Throughput::start();
    let mut submit_times: std::collections::HashMap<u64, Instant> =
        std::collections::HashMap::new();
    let start = Instant::now();
    let mut next = 0;
    loop {
        let now = start.elapsed().as_secs_f64();
        while next < arrivals.len() && arrivals[next].0 <= now {
            let (_, req) = &arrivals[next];
            submit_times.insert(req.id, Instant::now());
            engine.submit(req.clone());
            next += 1;
        }
        if engine.idle() {
            if next >= arrivals.len() {
                break;
            }
            // idle but next arrival is in the future: wait for it
            let wait = arrivals[next].0 - now;
            std::thread::sleep(Duration::from_secs_f64(wait.max(0.0)));
            continue;
        }
        for c in engine.pump().expect("engine pump") {
            let started = submit_times.remove(&c.id).unwrap();
            lat.record(started.elapsed());
            thr.observe(c.nfes);
        }
    }
    Some(LoadResult {
        name: name.to_owned(),
        wall: start.elapsed(),
        completed: thr.completed,
        nfes: thr.nfes,
        occupancy: engine.mean_occupancy(),
        lat,
    })
}

fn main() {
    let args = Args::from_env();
    let requests = args.usize("requests", 48);
    let rate = args.f64("rate", 4.0); // arrivals per second
    let steps = args.usize("steps", 20);
    let model = args.get_or("model", "dit_b").to_owned();
    let gamma_bar = args.f64("gamma-bar", 0.9988);
    let policies = args.get_or("policies", "cfg,ag,cond").to_owned();
    let scheduler = SchedulerKind::parse(args.get_or("scheduler", "fifo"))
        .unwrap_or_else(|e| panic!("--scheduler: {e}"));

    println!(
        "# E2E serving: {requests} requests, Poisson rate {rate}/s, model {model}, \
         T={steps}, scheduler {}\n",
        scheduler.name()
    );

    // every traffic row goes through the PolicySpec registry, so any
    // registered policy name works here (the list is comma-split, so use
    // bare names; per-policy parameters come from the shared flags).
    let registry = PolicyRegistry::builtin();
    let runs: Vec<LoadResult> = policies
        .split(',')
        .filter_map(|name| {
            let mut spec = match PolicySpec::parse(name.trim()) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("skipping `{name}`: {e}");
                    return None;
                }
            };
            spec.set_default("s", json::num(args.f64("guidance", 7.5)));
            if spec.canonical_kind() == "ag" {
                spec.set_default("gamma_bar", json::num(gamma_bar));
            }
            let policy = match registry.build(&spec) {
                Ok(p) => p,
                Err(e) => {
                    eprintln!("skipping `{name}`: {e}");
                    return None;
                }
            };
            let label = policy.name();
            drive(policy, &label, requests, rate, steps, &model, scheduler)
        })
        .collect();
    if runs.is_empty() {
        return;
    }

    let rows: Vec<Vec<String>> = runs
        .iter()
        .map(|r| {
            vec![
                r.name.clone(),
                r.completed.to_string(),
                format!("{:.1}", r.completed as f64 / r.wall.as_secs_f64()),
                format!("{:.0}", r.nfes as f64 / r.wall.as_secs_f64()),
                format!("{:.0}", r.lat.mean()),
                format!("{:.0}", r.lat.percentile(50.0)),
                format!("{:.0}", r.lat.percentile(99.0)),
                format!("{:.1}", r.occupancy),
            ]
        })
        .collect();
    print_table(
        &["traffic", "done", "img/s", "NFE/s", "mean ms", "p50 ms", "p99 ms", "occupancy"],
        &rows,
    );
    if runs.len() >= 2 {
        println!(
            "\n{} vs {}: {:.1}% lower mean latency, {:.2}x throughput \
             (NFE saving flows straight to serving capacity).",
            runs[1].name,
            runs[0].name,
            100.0 * (1.0 - runs[1].lat.mean() / runs[0].lat.mean()),
            (runs[1].completed as f64 / runs[1].wall.as_secs_f64())
                / (runs[0].completed as f64 / runs[0].wall.as_secs_f64())
        );
    }
}
