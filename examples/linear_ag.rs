//! LINEARAG end-to-end (paper §5.1 / App. C): collect CFG trajectories from
//! the serving engine, fit the per-step OLS estimators (Eq. 8) in Rust,
//! then serve with the ζ_LINEARAG policy (Eq. 11) — unconditional network
//! calls replaced by affine combinations of past scores.
//!
//! ```sh
//! cargo run --release --example linear_ag -- --train 160
//! ```

use std::sync::Arc;

use adaptive_guidance::coordinator::engine::Engine;
use adaptive_guidance::coordinator::policy::{cfg, linear_ag};
use adaptive_guidance::eval::harness::{mean_std, run_policy, ssim_series, RunSpec};
use adaptive_guidance::ols;
use adaptive_guidance::prompts;
use adaptive_guidance::runtime;
use adaptive_guidance::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let Some(be) = runtime::try_load_default() else { return Ok(()) };
    let img = be.manifest.img;
    let n_train = args.usize("train", 160);
    let steps = args.usize("steps", 20);
    let s = args.f32("guidance", 7.5);
    let model = args.get_or("model", "dit_b").to_owned();
    let mut engine = Engine::new(be)?;

    // 1) record trajectories (the paper: 200 paths, fit in < 20 minutes)
    println!("recording {n_train} CFG trajectories on {model}…");
    let mut spec = RunSpec::new(&model, steps);
    spec.record_trajectory = true;
    spec.seed_base = 77_000;
    let train_ps = prompts::eval_set(n_train, 3);
    let t0 = std::time::Instant::now();
    let rec = run_policy(&mut engine, &train_ps, &spec, cfg(s))?;
    let trajs: Vec<_> = rec
        .completions
        .into_iter()
        .map(|c| c.trajectory.unwrap())
        .collect();

    // 2) fit the per-step scalar-coefficient OLS (Eq. 8)
    let coeffs = ols::fit(&trajs, 1e-4);
    let mse = ols::eval_mse(&coeffs, &trajs);
    println!(
        "fitted {} regressions in {:.1}s; per-step MSE range [{:.5}, {:.5}]",
        steps,
        t0.elapsed().as_secs_f64(),
        mse.iter().cloned().fold(f64::INFINITY, f64::min),
        mse.iter().cloned().fold(0.0, f64::max)
    );
    println!(
        "most recent regressors dominate (paper App. C): β_c at step 10 = {:?}",
        coeffs.beta_c[10]
            .iter()
            .map(|b| (b * 1e3).round() / 1e3)
            .collect::<Vec<_>>()
    );

    // 3) serve fresh prompts under ζ_LINEARAG vs CFG
    let eval_ps = prompts::eval_set(32, 42);
    let eval_spec = RunSpec::new(&model, steps);
    let baseline = run_policy(&mut engine, &eval_ps, &eval_spec, cfg(s))?;
    let linear = run_policy(&mut engine, &eval_ps, &eval_spec,
                            linear_ag(s, Arc::new(coeffs)))?;
    let (sm, ss) = mean_std(&ssim_series(&linear, &baseline, img));
    println!(
        "\nLINEARAG: {:.1} NFEs/img vs CFG {:.1} ({:.0}% guidance-NFE saving), \
         SSIM vs baseline {:.3}±{:.3}",
        linear.mean_nfes(),
        baseline.mean_nfes(),
        100.0 * (baseline.mean_nfes() - linear.mean_nfes())
            / (baseline.mean_nfes() - steps as f64),
        sm,
        ss
    );
    println!("(the paper positions LINEARAG as a proof of concept: it no longer \
              replicates the baseline one-to-one.)");
    Ok(())
}
