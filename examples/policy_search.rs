//! Rust-driven differentiable NAS search (paper §4): the Lion optimizer
//! walks the per-step guidance scores α against the AOT'd search-gradient
//! module, then the learned α is extracted as a discrete policy and run.
//!
//! ```sh
//! cargo run --release --example policy_search -- --iters 40
//! ```

use adaptive_guidance::coordinator::engine::Engine;
use adaptive_guidance::coordinator::policy::{Cfg, Policy};
use adaptive_guidance::eval::harness::{mean_std, run_policy, ssim_series, RunSpec};
use adaptive_guidance::prompts::{self, Prompt};
use adaptive_guidance::runtime;
use adaptive_guidance::search::{run_search, SearchConfig};
use adaptive_guidance::util::cli::Args;
use adaptive_guidance::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let Some(mut be) = runtime::try_load_default() else { return Ok(()) };
    let meta = be.manifest.search.clone();
    let img = be.manifest.img;
    let cfg = SearchConfig {
        steps: meta.steps,
        options: meta.options.len(),
        batch: meta.batch,
        latent_len: be.manifest.flat_dim,
        iters: args.usize("iters", 40),
        lr: args.f32("lr", 0.02),
        seed: args.u64("seed", 0),
    };
    println!(
        "searching over {} policies ({} steps × {} options), {} Lion iterations…\n",
        (cfg.options as f64).powi(cfg.steps as i32),
        cfg.steps,
        cfg.options,
        cfg.iters
    );
    let mut grad = |a: &[f32], g: &[f32], x: &[f32], t: &[i32]| be.run_search_grad(a, g, x, t);
    let res = run_search(&mut grad, &cfg, |rng: &mut Rng| {
        Prompt::nth(rng.below(Prompt::space_size())).tokens()
    })?;

    // α heat-map (text): one row per step, one column per option
    println!("learned softmax(α) — {:?}", meta.options);
    for (t, row) in res.scores().iter().enumerate() {
        let cells: Vec<String> = row.iter().map(|p| format!("{p:.2}")).collect();
        let best = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        println!("  step {t:>2}: [{}] → {}", cells.join(" "), meta.options[best]);
    }

    // run the extracted policy vs the CFG baseline
    let policy = res.extract_policy(meta.s_base as f32);
    let Some(be2) = runtime::try_load_default() else { return Ok(()) };
    let mut engine = Engine::new(be2)?;
    let ps = prompts::eval_set(32, 42);
    let spec = RunSpec::new("dit_s", meta.steps);
    let baseline = run_policy(&mut engine, &ps, &spec,
                              Cfg { s: meta.s_base as f32 }.into_ref())?;
    let searched = run_policy(&mut engine, &ps, &spec, policy.into_ref())?;
    let (sm, ss) = mean_std(&ssim_series(&searched, &baseline, img));
    println!(
        "\nextracted policy: {:.1} NFEs/img (CFG: {:.1}), SSIM vs baseline {:.3}±{:.3}",
        searched.mean_nfes(),
        baseline.mean_nfes(),
        sm,
        ss
    );
    Ok(())
}
