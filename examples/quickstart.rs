//! Quickstart: load the AOT'd artifacts, generate a few images under
//! Adaptive Guidance, and compare the cost against plain CFG.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use adaptive_guidance::coordinator::engine::Engine;
use adaptive_guidance::coordinator::policy::{ag, cfg};
use adaptive_guidance::coordinator::request::Request;
use adaptive_guidance::prompts::Prompt;
use adaptive_guidance::runtime;
use adaptive_guidance::util::ppm;

fn main() -> anyhow::Result<()> {
    let Some(be) = runtime::try_load_default() else { return Ok(()) };
    let img = be.manifest.img;
    let mut engine = Engine::new(be)?;

    let prompt = Prompt::parse("a large red circle at the center").unwrap();
    println!("prompt: \"{}\" (tokens {:?})\n", prompt.text(), prompt.tokens());

    // Same seed, two policies: CFG (the baseline) and Adaptive Guidance.
    let cfg_req = Request::new(0, "dit_b", prompt.tokens(), 7, 20, cfg(7.5));
    let ag_req = Request::new(1, "dit_b", prompt.tokens(), 7, 20,
                              ag(7.5, 0.9988));
    let out = engine.run(vec![cfg_req, ag_req])?;

    std::fs::create_dir_all("out")?;
    for (c, name) in out.iter().zip(["cfg", "ag"]) {
        let up = ppm::upscale(&c.image, img, img, 8);
        let path = format!("out/quickstart_{name}.ppm");
        ppm::write_ppm(std::path::Path::new(&path), &up, img * 8, img * 8)?;
        println!(
            "{name:>4}: {} NFEs{}  -> {path}",
            c.nfes,
            c.truncated_at
                .map(|t| format!(" (guidance dropped after step {t})"))
                .unwrap_or_default(),
        );
    }
    let ssim = adaptive_guidance::quality::ssim::ssim_rgb(
        &out[0].image, &out[1].image, img, img);
    println!(
        "\nAG replicated CFG at SSIM {:.4} while saving {} NFEs ({:.0}%).",
        ssim,
        out[0].nfes - out[1].nfes,
        100.0 * (out[0].nfes - out[1].nfes) as f64 / out[0].nfes as f64
    );
    println!("gamma trace (Eq. 7): {:?}",
             out[0].gammas.iter().map(|g| (g * 1e4).round() / 1e4).collect::<Vec<_>>());
    Ok(())
}
