#!/usr/bin/env bash
# Trace smoke (§Observability): the capture → profile loop end-to-end
# against a real `agd serve` process — one `"trace": true` request whose
# completion line must echo a timeline, a `{"cmd": "spans"}` drain, and
# `agd profile` over the drained capture producing non-empty Chrome
# trace JSON plus the stage/ledger tables.
#
#   scripts/trace_smoke.sh                 -> PROFILE_trace.json in the repo root
#   TRACE_PORT=7777 scripts/trace_smoke.sh -> custom port (default 7498)
#
# Requires the Rust toolchain; scripts/tier1.sh invokes it behind the
# same availability check it applies to clippy/rustfmt.
set -euo pipefail
cd "$(dirname "$0")/.."

port="${TRACE_PORT:-7498}"
addr="127.0.0.1:${port}"
spans="$(mktemp /tmp/agd_trace_spans.XXXXXX.json)"
trap 'rm -f "$spans"; [[ -n "${server_pid:-}" ]] && kill "$server_pid" 2>/dev/null || true' EXIT

cargo build --release --bin agd
agd=target/release/agd

"$agd" serve --backend gmm --shards 2 --addr "$addr" &
server_pid=$!

# readiness: probe the TCP port itself rather than parsing the banner
for _ in $(seq 1 100); do
    if (exec 3<>"/dev/tcp/127.0.0.1/${port}") 2>/dev/null; then
        exec 3>&- 3<&-
        break
    fi
    sleep 0.1
done

# one traced request + one untraced one, then drain the span rings —
# all on one connection (the line protocol replies in order)
reply="$(
    exec 3<>"/dev/tcp/127.0.0.1/${port}"
    printf '%s\n' \
        '{"prompt": "red circle", "policy": "ag", "steps": 8, "guidance": 2.0, "trace": true}' \
        '{"prompt": "blue square", "policy": "cfg", "steps": 8, "guidance": 2.0}' \
        '{"cmd": "spans"}' >&3
    head -n 3 <&3
)"

# line 1: the traced completion must carry its timeline inline
printf '%s\n' "$reply" | sed -n '1p' | grep -q '"timeline":' \
    || { echo "trace_smoke: no timeline on the traced completion" >&2; exit 1; }
# line 3: the drained rings must hold events
printf '%s\n' "$reply" | sed -n '3p' > "$spans"
grep -q '"guidance"' "$spans" \
    || { echo "trace_smoke: spans drain holds no guidance events" >&2; exit 1; }

kill "$server_pid" 2>/dev/null || true
wait "$server_pid" 2>/dev/null || true
server_pid=""

# profile leg: the drained capture parses and renders non-empty
"$agd" profile --spans "$spans" --out PROFILE_trace.json
grep -q '"traceEvents":\[{' PROFILE_trace.json \
    || { echo "trace_smoke: PROFILE_trace.json holds no trace events" >&2; exit 1; }

echo "trace_smoke: OK (wrote PROFILE_trace.json)"
