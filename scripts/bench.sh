#!/usr/bin/env bash
# Perf baseline runner: executes the §Perf microbenchmarks and writes the
# machine-readable trajectory (BENCH_perf.json) that optimization PRs
# commit their before/after numbers into.
#
#   scripts/bench.sh                 -> BENCH_perf.json in the repo root
#   scripts/bench.sh out.json        -> custom output path
#   BENCH_ITERS=50 scripts/bench.sh  -> more timed iterations per row
#
# The dump includes the packed-vs-legacy engine-loop pair and the
# workers=1/2/4/8 scaling sweep (expect >=2x per-NFE throughput at 4
# workers on a 4-core host; results are bit-identical at every width).
set -euo pipefail
cd "$(dirname "$0")/.."

out="${1:-BENCH_perf.json}"
iters="${BENCH_ITERS:-30}"

cargo bench --bench perf_microbench -- --iters "$iters" --out "$out"
echo "bench: wrote $out"
