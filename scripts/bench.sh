#!/usr/bin/env bash
# Perf baseline runner: executes the §Perf microbenchmarks and writes the
# machine-readable trajectory (BENCH_perf.json) that optimization PRs
# commit their before/after numbers into.
#
#   scripts/bench.sh                 -> BENCH_perf.json in the repo root
#   scripts/bench.sh out.json        -> custom output path
#   BENCH_ITERS=50 scripts/bench.sh  -> more timed iterations per row
#
# The dump includes the packed-vs-legacy engine-loop pair, the
# workers=1/2/4/8 scaling sweep (expect >=2x per-NFE throughput at 4
# workers on a 4-core host; results are bit-identical at every width),
# and the fleet shard-scaling sweep (shards=1/2/4 virtual-time p50/p99
# from sched_tail_latency, merged under "sched_shard_sweep" — expect p99
# to fall as shards grow at fixed arrival rate).
set -euo pipefail
cd "$(dirname "$0")/.."

out="${1:-BENCH_perf.json}"
iters="${BENCH_ITERS:-30}"

cargo bench --bench perf_microbench -- --iters "$iters" --out "$out"
cargo bench --bench sched_tail_latency -- --shards-sweep 1,2,4 --merge-into "$out"
# §Scale: the front-end sweep — reactor vs thread-per-connection at
# 32/256/1024 persistent connections, closed-loop (merged under
# "conn_scaling"; expect reactor req/s to hold flat as conns grow)
cargo bench --bench conn_scaling -- --conns-sweep 32,256,1024 --merge-into "$out"
echo "bench: wrote $out"
