#!/usr/bin/env bash
# Chaos + replay smoke (§Robustness): runs the scenario corpus through
# the chaos integration suite, then exercises the full CLI loop on
# localhost — `agd serve --trace-out` capturing a replayed sample trace,
# then `agd replay` of that capture digest-checking every completion.
#
#   scripts/chaos.sh                 -> BENCH_replay.json in the repo root
#   CHAOS_PORT=7777 scripts/chaos.sh -> custom port (default 7497)
#
# Requires the Rust toolchain; scripts/tier1.sh invokes it behind the
# same availability check it applies to clippy/rustfmt.
set -euo pipefail
cd "$(dirname "$0")/.."

port="${CHAOS_PORT:-7497}"
addr="127.0.0.1:${port}"
capture="$(mktemp /tmp/agd_chaos_capture.XXXXXX.jsonl)"
trap 'rm -f "$capture"; [[ -n "${server_pid:-}" ]] && kill "$server_pid" 2>/dev/null || true' EXIT

# 1. the scenario corpus against a live in-process fleet
cargo test -q --test chaos_integration

# 2. the CLI loop: a real `agd serve` process on localhost
cargo build --release --bin agd
agd=target/release/agd

rm -f "$capture"
"$agd" serve --backend gmm --shards 2 --addr "$addr" --trace-out "$capture" &
server_pid=$!

# readiness: probe the TCP port itself rather than parsing the banner
for _ in $(seq 1 100); do
    if (exec 3<>"/dev/tcp/127.0.0.1/${port}") 2>/dev/null; then
        exec 3>&- 3<&-
        break
    fi
    sleep 0.1
done

# capture leg: replay the checked-in sample trace into the tracing server
"$agd" replay --trace scenarios/sample_trace.jsonl --addr "$addr" \
    --speed 50 --connections 8 --out /dev/null

# verify leg: replay the capture back at the same server; every
# completion is digest-checked against what was served at capture time
# (agd replay exits non-zero on any mismatch)
"$agd" replay --trace "$capture" --addr "$addr" \
    --speed 20 --connections 4 --out BENCH_replay.json

kill "$server_pid" 2>/dev/null || true
wait "$server_pid" 2>/dev/null || true
server_pid=""

# survival leg (§Robustness): the same capture against a fleet taking
# scheduled backend faults, with batch retries and shard respawn armed.
# Every digest must STILL match — faults the fleet absorbs change when
# work runs, never its bytes — and the final BENCH_replay.json carries
# the survived_* counters scraped from {"cmd": "stats"} post-run.
"$agd" serve --backend gmm --shards 2 --addr "$addr" \
    --fault-spec error-every=3 --max-batch-retries 6 --shard-respawn &
server_pid=$!
for _ in $(seq 1 100); do
    if (exec 3<>"/dev/tcp/127.0.0.1/${port}") 2>/dev/null; then
        exec 3>&- 3<&-
        break
    fi
    sleep 0.1
done
"$agd" replay --trace "$capture" --addr "$addr" \
    --speed 20 --connections 4 --out BENCH_replay.json
grep -q "survived_batch_retries" BENCH_replay.json

kill "$server_pid" 2>/dev/null || true
wait "$server_pid" 2>/dev/null || true
server_pid=""

# resume leg (§Robustness): shard 0 dies fatally mid-run with per-step
# checkpointing armed, so its started requests resume on shard 1 instead
# of being replayed from scratch. The digest check is the point: resumed
# completions must be byte-identical to the capture-time (fault-free)
# bytes. shard=0: targets the fault so the survivor stays transparent.
"$agd" serve --backend gmm --shards 2 --addr "$addr" \
    --checkpoint-steps 1 --fault-spec shard=0:fail-after=20 --shard-respawn &
server_pid=$!
for _ in $(seq 1 100); do
    if (exec 3<>"/dev/tcp/127.0.0.1/${port}") 2>/dev/null; then
        exec 3>&- 3<&-
        break
    fi
    sleep 0.1
done
"$agd" replay --trace "$capture" --addr "$addr" \
    --speed 20 --connections 4 --out BENCH_replay.json
grep -q "survived_shard_deaths" BENCH_replay.json

kill "$server_pid" 2>/dev/null || true
wait "$server_pid" 2>/dev/null || true
server_pid=""
echo "chaos: OK (wrote BENCH_replay.json, survival counters included)"
