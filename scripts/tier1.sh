#!/usr/bin/env bash
# Tier-1 verify: the gate every PR must keep green.
#
#   scripts/tier1.sh            build + tests + lint + formatting
#   scripts/tier1.sh --no-fmt   skip the formatting check (CI images
#                               without rustfmt)
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release
# bench targets are not covered by `cargo build`/`cargo test`; compile them
# explicitly so they cannot rot on CI images without clippy (which would
# otherwise be the only thing building --all-targets)
cargo build --release --benches
cargo test -q
# the fleet invariant (byte-identical results across shard counts and
# placements) is the scale-out safety net — run its suite explicitly so a
# filtered/partial `cargo test` configuration can never silently skip it
cargo test -q --test fleet_integration
# the fault wrapper must stay free when not firing: it sits on every
# serving shard's denoise path unconditionally, so a regression here is
# a per-batch allocation tax on every deployment
cargo test -q --test fault_zero_alloc
# checkpoint-armed pump must also stay allocation-free: with
# --checkpoint-steps 1 every completed step captures a snapshot, and all
# of it has to land in buffers sized at admission
cargo test -q --test ckpt_zero_alloc
# the serving front-end contract (§Scale): reactor vs threads byte
# parity, pipelined wire ids, wire-level cancellation with admission
# refund, progress streaming, and the 1024-connection event loop
cargo test -q --test reactor_integration
# the robustness invariant (faults change who is served, never what):
# scenario corpus (incl. backend_fault_storm + shard_respawn) +
# capture->replay digest check, then the same replay against a fleet
# taking scheduled faults with retries/respawn armed
scripts/chaos.sh
# the observability loop (§Observability): a traced request echoes its
# lifecycle timeline, {"cmd": "spans"} drains the rings, and
# `agd profile` renders the capture into non-empty Chrome trace JSON
scripts/trace_smoke.sh

if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "tier1: clippy unavailable, skipping lint" >&2
fi

if [[ "${1:-}" != "--no-fmt" ]]; then
    if cargo fmt --version >/dev/null 2>&1; then
        cargo fmt --check
    else
        echo "tier1: rustfmt unavailable, skipping format check" >&2
    fi
fi

echo "tier1: OK"
